import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
if "--xla_force_host_platform_device_count" not in os.environ["XLA_FLAGS"]:
    os.environ["XLA_FLAGS"] += " --xla_force_host_platform_device_count=512"

"""Roofline analysis from the compiled dry-run (no hardware required).

Three terms per (arch × shape × mesh), in seconds per step:

    compute    = HLO_FLOPs_per_chip   / peak_FLOP/s
    memory     = HLO_bytes_per_chip   / HBM_bw
    collective = coll_bytes_per_chip  / link_bw

**Scan correction.**  XLA's ``cost_analysis`` counts a while-loop body once
regardless of trip count, so a scanned 80-layer model reports ~1 layer of
FLOPs.  Every scan in the model stack goes through ``instrumented_scan``
(models/scan.py), which records the body + abstract carry/x during a
(cheap) ``eval_shape`` trace.  We lower each recorded body *separately*
under the same mesh/rules and apply, recursively,

    corrected(node) = cost(node) + Σ_child [ len(child)·corrected(child)
                                             − cost(child) ]

where cost(·) is the per-device compiled cost of a single body.  The
subtraction removes the once-counted in-context copy; the residual
mismatch (fusion differs slightly in/out of context) is second-order.

MODEL_FLOPS (analytic 6·N·D for training, 2·N_active·tokens + cache reads
for decode) is reported alongside, and the ratio MODEL_FLOPS/HLO_FLOPs
flags remat/redundancy waste.
"""

import argparse
import json
import pathlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.launch import hlo
from repro.launch.mesh import V5E, make_production_mesh
from repro.launch.shapes import SHAPES, ShapeSpec
from repro.launch.steps import Cell, build_cell
from repro.models import Model, get_config, list_configs
from repro.models.config import ArchConfig, MOE
from repro.models.params import count_params, is_def
from repro.models.scan import ScanCollector, ScanRecord
from repro.models.sharding import sharding_rules

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results"


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        coll = dict(self.coll)
        for k, v in o.coll.items():
            coll[k] = coll.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes, coll)

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _measure_compiled(compiled) -> Cost:
    text = compiled.as_text()
    st = hlo.collective_stats(text)
    return Cost(hlo.flop_count(compiled), hlo.bytes_accessed(compiled),
                {k: float(v) for k, v in st.bytes_by_kind.items()})


def _ct_like(o):
    import jax.numpy as jnp

    if jnp.issubdtype(o.dtype, jnp.inexact):
        return jnp.ones_like(o)
    return np.zeros(o.shape, dtype=jax.dtypes.float0)


def _lower_body(rec: ScanRecord, cell: Cell, with_grad: bool = False) -> Cost:
    """Per-device compiled cost of one scan-body iteration, lowered with the
    true input shardings (from the call site's recorded logical axes).

    ``with_grad``: for training cells the compiled program contains the scan
    body once in the forward while-loop *and* its transpose once in the
    backward while-loop; the per-iteration cost that multiplies by the trip
    count is therefore fwd+vjp of one body (remat included — the body
    carries its own ``jax.checkpoint``).
    """
    from jax.sharding import NamedSharding

    from repro.models.sharding import Ax, logical_to_spec

    if with_grad:
        def wrapped(carry, x):
            with sharding_rules(cell.rules, cell.mesh):
                out, vjp = jax.vjp(lambda c, xx: rec.body(c, xx), carry, x)
                cts = jax.tree.map(_ct_like, out)
                return out, vjp(cts)
    else:
        def wrapped(carry, x):
            with sharding_rules(cell.rules, cell.mesh):
                return rec.body(carry, x)

    args = (rec.carry_sds,) + ((rec.x_sds,)
                               if rec.x_sds is not None else (None,))
    in_sh = None
    if rec.logical_axes is not None:
        axis_size = dict(zip(cell.mesh.axis_names, cell.mesh.devices.shape))

        with sharding_rules(cell.rules, cell.mesh):
            def to_ns(axv, sds):
                spec = logical_to_spec(axv.axes)
                # drop entries whose dimension is not divisible by the mesh
                # extent (e.g. 1500 audio frames over a 16-way axis) — the
                # full program handles these with GSPMD padding, but jit
                # in_shardings requires exact divisibility.
                entries = list(spec) + [None] * (len(sds.shape) - len(spec))
                fixed = []
                for dim, entry in zip(sds.shape, entries):
                    if entry is None:
                        fixed.append(None)
                        continue
                    parts = entry if isinstance(entry, tuple) else (entry,)
                    n = 1
                    for p in parts:
                        n *= axis_size.get(p, 1)
                    fixed.append(entry if dim % n == 0 else None)
                from jax.sharding import PartitionSpec as P

                return NamedSharding(cell.mesh, P(*fixed))

            carry_ax, x_ax = rec.logical_axes
            in_sh = (jax.tree.map(to_ns, carry_ax, rec.carry_sds,
                                  is_leaf=lambda v: isinstance(v, Ax)),)
            if rec.x_sds is not None:
                in_sh = in_sh + (jax.tree.map(
                    to_ns, x_ax, rec.x_sds,
                    is_leaf=lambda v: isinstance(v, Ax)),)
            else:
                in_sh = in_sh + (None,)
    with cell.mesh:
        jitted = (jax.jit(wrapped, in_shardings=in_sh)
                  if in_sh is not None else jax.jit(wrapped))
        compiled = jitted.lower(*args).compile()
    return _measure_compiled(compiled)


def _corrected(rec: ScanRecord, cell: Cell, cache: Dict[int, Cost],
               with_grad: bool) -> Cost:
    if id(rec) in cache:
        return cache[id(rec)]
    cost = _lower_body(rec, cell, with_grad)
    for child in rec.children:
        child_once = _lower_body(child, cell, with_grad)
        cost = cost + _corrected(child, cell, cache,
                                 with_grad).scaled(child.length) \
            + child_once.scaled(-1.0)
    cache[id(rec)] = cost
    return cost


# ---------------------------------------------------------------------------
# analytic model FLOPs
# ---------------------------------------------------------------------------

def active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: routed top-k + shared only)."""
    model = Model(cfg)
    defs = model.param_defs()
    total = count_params(defs)
    if not cfg.num_experts:
        return total
    # subtract inactive experts: each MoE block's expert tensors scale by
    # (E − k)/E
    from repro.models.moe import moe_defs

    per_block = count_params(moe_defs(cfg)) - count_params(
        {k: v for k, v in moe_defs(cfg).items() if k.startswith("shared")})
    # router is tiny; treat all non-shared expert params as routed
    n_moe_blocks = (list(cfg.pattern).count(MOE) * cfg.pattern_repeats
                    + list(cfg.tail).count(MOE))
    routed = per_block * n_moe_blocks
    inactive_frac = 1.0 - cfg.experts_per_token / cfg.num_experts
    return int(total - routed * inactive_frac)


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # 6ND matmul + attention quadratic term (fwd 2·B·S²·H·hd·2, ×3 bwd)
        attn_layers = sum(
            1 for k in (list(cfg.pattern) * cfg.pattern_repeats
                        + list(cfg.tail))
            if k in ("attn", "local", "dense", "moe", "shared_attn", "cross"))
        hd = cfg.resolved_head_dim
        attn = 12 * shape.global_batch * shape.seq_len ** 2 \
            * cfg.num_heads * hd * attn_layers / 2  # /2: causal
        return 6.0 * n_active * tokens + attn
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        attn_layers = sum(
            1 for k in (list(cfg.pattern) * cfg.pattern_repeats
                        + list(cfg.tail))
            if k in ("attn", "local", "dense", "moe", "shared_attn", "cross"))
        hd = cfg.resolved_head_dim
        attn = 4 * shape.global_batch * shape.seq_len ** 2 \
            * cfg.num_heads * hd * attn_layers / 2
        return 2.0 * n_active * tokens + attn
    # decode: one token per sequence
    tokens = shape.global_batch
    attn_layers = sum(
        1 for k in (list(cfg.pattern) * cfg.pattern_repeats + list(cfg.tail))
        if k in ("attn", "local", "dense", "moe", "shared_attn", "cross"))
    hd = cfg.resolved_head_dim
    cache_reads = 4.0 * tokens * shape.seq_len * cfg.num_heads * hd \
        * attn_layers
    return 2.0 * n_active * tokens + cache_reads


def model_bytes_per_chip(cfg: ArchConfig, shape: ShapeSpec, chips: int,
                         tp: int) -> float:
    """Analytic minimum HBM traffic per chip per step (bytes).

    ``cost_analysis()['bytes accessed']`` counts every HLO operand — an
    upper bound that ignores fusion (on the CPU backend, wildly so).  The
    roofline memory term instead uses this explicit traffic model; the HLO
    number is reported alongside as the unfused upper bound.

    train   : params 3 reads (fwd, bwd, opt) + grad write/read + optimizer
              moments read+write + residual-stream carries write+2·reads
              + logits stream.
    prefill : params once + KV-state write + activations once.
    decode  : params once + whole decode state read + one-slot write (the
              classic decode bound: state+weights stream per token).
    """
    model = Model(cfg)
    pdefs = model.param_defs()
    from repro.models.params import param_bytes

    p_bytes = param_bytes(pdefs) / chips
    d = cfg.d_model
    if shape.kind == "train":
        opt_bytes = 2 * 4 * (param_bytes(pdefs) // 2) / chips   # m+v fp32
        acts = (cfg.num_layers * shape.global_batch * shape.seq_len * d * 2
                / chips)                                        # bf16 carries
        logits = shape.global_batch * shape.seq_len * cfg.vocab_size * 4 \
            / chips
        return 5 * p_bytes + 2 * opt_bytes + 3 * acts + 2 * logits
    state_defs = model.decode_state_defs(shape.global_batch, shape.seq_len)
    from repro.models.params import param_bytes as pb

    state_bytes = pb(state_defs) / chips
    if shape.kind == "prefill":
        acts = (cfg.num_layers * shape.global_batch * shape.seq_len * d * 2
                / chips)
        return p_bytes + state_bytes + 2 * acts
    # decode: stream weights + read the whole state once, write one slot
    return p_bytes + state_bytes


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------

def analyze_cell(arch: str, shape_name: str, *, mesh_kind: str = "single",
                 hw=V5E, verbose: bool = True, overrides=None,
                 rule_overrides=None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    cell = build_cell(arch, shape_name, mesh, overrides=overrides,
                      rule_overrides=rule_overrides)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "entry": cell.entry,
           "overrides": overrides or {}, "rule_overrides":
           {k: str(v) for k, v in (rule_overrides or {}).items()}}
    if cell.skipped:
        rec["status"] = "skipped"
        rec["reason"] = cell.skipped
        return rec

    # 1) scan tree from a cheap abstract trace
    with ScanCollector() as col:
        jax.eval_shape(cell.fn, *cell.args_abs)

    # 2) whole-program compiled cost (bodies counted once)
    with cell.mesh:
        compiled = cell.lower().compile()
    root = _measure_compiled(compiled)
    mem = hlo.memory_stats(compiled)

    # 3) scan-corrected totals (train: fwd+vjp per body — the compiled
    # program holds body-once in the fwd loop and transpose-once in the bwd)
    with_grad = cell.entry == "train_step"
    cache: Dict[int, Cost] = {}
    total = root
    scans = []
    for child in col.root.children:
        once = _lower_body(child, cell, with_grad)
        corr = _corrected(child, cell, cache, with_grad)
        total = total + corr.scaled(child.length) + once.scaled(-1.0)
        scans.append({"name": child.name, "length": child.length,
                      "body_flops": once.flops,
                      "children": len(child.children)})

    cfg = cell.cfg                      # includes perf-variant overrides
    shape = SHAPES[shape_name]
    mf = model_flops(cfg, shape)
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    mbytes = model_bytes_per_chip(cfg, shape, chips, tp)
    compute_s = total.flops / hw.peak_flops
    memory_s = mbytes / hw.hbm_bw
    coll_s = total.coll_bytes / hw.ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mfu = (mf / chips / hw.peak_flops) / step_s if step_s > 0 else 0.0

    rec.update(
        status="ok",
        hlo_flops_per_chip=total.flops,
        hlo_bytes_per_chip=total.bytes,
        model_bytes_per_chip=mbytes,
        hlo_bytes_upper_bound_s=total.bytes / hw.hbm_bw,
        coll_bytes_per_chip=total.coll_bytes,
        coll_by_kind=total.coll,
        uncorrected_flops=root.flops,
        terms=terms,
        dominant=dominant,
        model_flops_total=mf,
        model_flops_per_chip=mf / chips,
        useful_ratio=(mf / chips) / total.flops if total.flops else 0.0,
        roofline_fraction=mfu,
        scans=scans,
        memory=mem,
    )
    if verbose:
        print(f"[roofline] {arch} × {shape_name} × {mesh_kind}: "
              f"compute={compute_s*1e3:.2f}ms memory={memory_s*1e3:.2f}ms "
              f"collective={coll_s*1e3:.2f}ms → {dominant.split('_')[0]}-bound; "
              f"MODEL/HLO={rec['useful_ratio']:.2f} "
              f"roofline-frac={mfu:.3f}")
    return rec


def load_results() -> dict:
    f = RESULTS / "roofline.json"
    return json.loads(f.read_text()) if f.exists() else {}


def save_result(rec: dict, tag: str = "") -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    res = load_results()
    key = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
    if tag:
        key += f"|{tag}"
    res[key] = rec
    (RESULTS / "roofline.json").write_text(json.dumps(res, indent=1))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", default="", help="variant tag (perf iterations)")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override, e.g. --set moe_dispatch_groups=16")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule override, e.g. --rule expert_mlp=data"
                         " (use 'none' for unsharded)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    def _cast(v: str):
        for t in (int, float):
            try:
                return t(v)
            except ValueError:
                continue
        return {"true": True, "false": False, "none": None}.get(v.lower(), v)

    overrides = dict(kv.split("=", 1) for kv in args.set) or None
    if overrides:
        overrides = {k: _cast(v) for k, v in overrides.items()}
    rule_overrides = dict(kv.split("=", 1) for kv in args.rule) or None
    if rule_overrides:
        rule_overrides = {k: _cast(v) for k, v in rule_overrides.items()}
    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    existing = load_results()
    fails = 0
    for arch in archs:
        for shape in shapes:
            key = f"{arch}|{shape}|{args.mesh}" + (f"|{args.tag}" if args.tag
                                                   else "")
            if not args.force and existing.get(key, {}).get("status") == "ok":
                print(f"[roofline] {key}: cached")
                continue
            try:
                rec = analyze_cell(arch, shape, mesh_kind=args.mesh,
                                   overrides=overrides,
                                   rule_overrides=rule_overrides)
            except Exception as e:  # noqa: BLE001
                import traceback
                rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc(limit=15)}
                print(f"[roofline] {key}: FAIL {rec['error']}")
                fails += 1
            save_result(rec, args.tag)
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
