"""Assigned input shapes, per-cell applicability, and abstract input specs.

Every (architecture × shape) cell lowers one of three entry points:

  ``train_4k``    → train_step   (fwd + bwd + optimizer update)
  ``prefill_32k`` → prefill_step (prompt pass emitting the decode state)
  ``decode_32k``  → serve_step   (one token over a seq_len KV/SSM state)
  ``long_500k``   → serve_step   (B=1, 512k state; sub-quadratic archs only)

``input_specs`` returns weak-type-correct ShapeDtypeStructs — no allocation;
the dry-run lowers against them.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.models.config import ArchConfig
from repro.models.params import abstract, is_def
from repro.models.sharding import DEFAULT_RULES, Rules


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(applicable?, reason).  Per DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention stack: O(seq) KV state at 524k "
                       "exceeds sub-quadratic requirement; skipped per "
                       "assignment note")
    return True, ""


# ---------------------------------------------------------------------------
# sharding-rule resolution (divisibility-aware)
# ---------------------------------------------------------------------------

def resolve_rules(cfg: ArchConfig, shape: ShapeSpec, *,
                  tp: int, dp: int, fsdp: bool = True) -> Rules:
    """Concrete logical→physical rules for one (arch, shape, mesh) cell.

    Baseline layout: batch → (pod, data); Megatron TP over "model" for
    heads / kv / mlp / experts / vocab; sequence-parallel residual stream
    for full-sequence passes; FSDP (params' ``embed`` axis → "data") for
    training so optimizer state is fully sharded (ZeRO-3 style).

    Divisibility fallbacks (checked against the actual arch dims):
      * heads   % tp != 0  → heads unsharded, shard head_dim instead;
      * kv_heads % tp != 0 → kv replicated, KV head_dim sharded (keeps the
        decode KV cache distributed — the thing that must never replicate);
      * vocab is padded to a multiple of 256 in the model, always divisible.
    """
    rules: Dict[str, object] = dict(DEFAULT_RULES)
    hd = cfg.resolved_head_dim
    heads_ok = cfg.num_heads % tp == 0
    kv_ok = cfg.num_kv_heads % tp == 0
    hd_ok = hd % tp == 0

    rules["heads"] = "model" if heads_ok else None
    rules["kv_heads"] = "model" if kv_ok else None
    if (not heads_ok or not kv_ok) and hd_ok:
        rules["head_dim"] = "model"      # dedup keeps q/w_q consistent
    if cfg.d_ff and cfg.d_ff % tp != 0:
        rules["mlp"] = None
    if cfg.num_experts and cfg.num_experts % tp != 0:
        rules["experts"] = None
    if cfg.num_experts and cfg.d_model % dp != 0:
        rules["expert_embed"] = None
    if cfg.ssm_state and cfg.ssm_heads % tp != 0:
        rules["ssm_heads"] = None

    if shape.kind in ("train", "prefill"):
        # sequence-parallel residual stream (activations only; rides the
        # "model" axis between blocks, re-gathered inside attention)
        if shape.seq_len % tp == 0:
            rules["seq"] = "model"
    if shape.kind == "train" and fsdp:
        rules["embed"] = "data"          # ZeRO-3: params+opt fully sharded

    if shape.kind == "decode":
        if shape.global_batch % dp != 0:
            # long_500k: B=1 — shard the cache sequence instead of batch
            rules["batch"] = None
            rules["cache_batch"] = None
            rules["cache_seq"] = "data"
    return rules


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _frontend_sds(cfg: ArchConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    if cfg.is_encoder_decoder:
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    if cfg.vision_seq:
        return jax.ShapeDtypeStruct((batch, cfg.vision_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    return None


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, object]:
    """Abstract inputs for the cell's entry point (ShapeDtypeStructs)."""
    i32 = jnp.dtype("int32")
    if shape.kind == "train":
        b, s = shape.global_batch, shape.seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
        fe = _frontend_sds(cfg, b)
        if fe is not None:
            batch["frontend"] = fe
        return {"batch": batch}
    if shape.kind == "prefill":
        b, s = shape.global_batch, shape.seq_len
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        fe = _frontend_sds(cfg, b)
        if fe is not None:
            out["frontend"] = fe
        return out
    # decode
    b, s = shape.global_batch, shape.seq_len
    model = Model(cfg)
    state = abstract(model.decode_state_defs(b, s))
    return {
        "state": state,
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "position": jax.ShapeDtypeStruct((), i32),
    }
