"""Structured span tracing with deterministic, UUID-derived trace IDs.

The shim never mints random trace IDs: a workflow's trace ID is a stable
hash of its workflow UUID (``trace_id``), and every transaction UUID the
workflow machinery derives from it (``<uuid>.step.<name>``,
``<uuid>.memo.<step>``, ``<entry>.claim``) maps back to the same trace via
``txn_trace_id`` — so the trace context propagates client →
``WorkflowSession``/``StepTxnSession`` → ``AftNode.commit_transaction_async``
→ pipeline flush → ``ChainConsumer`` child claim *structurally*, with no
context object threaded through call signatures.  Kill-and-retry keeps the
same trace ID (same UUID) while each attempt gets a distinct span ID
(``span_id`` folds the attempt number in), and a chain child
(``<parent>.chain.<edge>``) starts a trace of its own, linked to the parent
trace on the claim/submit events.

Events are JSON-lines records, ring-buffered in memory and optionally
appended to a file sink (``REPRO_TRACE_FILE``).  The file is flushed on
every emit — spans are closed (and therefore durable) one by one, so a
kill-injected crash loses at most the spans still open, never the history
the offline checker (``repro.obs.checker``) replays.

Tracing is **globally off by default**: the module-level tracer is a
disabled instance whose ``emit`` returns immediately, and every
instrumentation site guards on ``tracer.enabled``, keeping the disabled
overhead to one attribute check.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = [
    "TRACE_FILE_ENV",
    "Tracer",
    "trace_id",
    "base_uuid",
    "txn_trace_id",
    "span_id",
    "get_tracer",
    "set_tracer",
    "enable",
    "disable",
    "configure_from_env",
]

TRACE_FILE_ENV = "REPRO_TRACE_FILE"

# Mirrors the derived-UUID grammar in core/records.py; duplicated literally
# so the obs layer (and the offline checker built on it) stays importable
# without repro.core.
_STEP_INFIXES = (".step.", ".memo.")
_CLAIM_SUFFIXES = (".claim", ".enq")


def trace_id(workflow_uuid: str) -> str:
    """Deterministic 16-hex-digit trace ID for a workflow UUID."""
    return hashlib.blake2b(str(workflow_uuid).encode("utf-8"),
                           digest_size=8).hexdigest()


def base_uuid(txn_uuid: str) -> str:
    """Strip the derived-transaction decorations off a UUID, recovering the
    workflow UUID that owns the trace.  ``.chain.`` infixes are kept: a
    chain child is its own workflow (and its own trace)."""
    u = str(txn_uuid)
    for suffix in _CLAIM_SUFFIXES:
        if u.endswith(suffix):
            u = u[: -len(suffix)]
    for infix in _STEP_INFIXES:
        idx = u.find(infix)
        if idx >= 0:
            u = u[:idx]
    return u


def txn_trace_id(txn_uuid: str) -> str:
    """Trace ID for any transaction UUID the workflow layer derives."""
    return trace_id(base_uuid(txn_uuid))


def span_id(trace: str, name: str, attempt: object = 0) -> str:
    """Span IDs fold an attempt qualifier in, so kill-and-retry replays
    (and same-UUID re-drives, which qualify with a run seed too) emit
    fresh spans instead of duplicate IDs."""
    return f"{trace}/{name}#{attempt}"


class _SpanCtx:
    __slots__ = ("_tracer", "name", "trace", "span", "parent",
                 "attrs", "_t0", "status")

    def __init__(self, tracer: "Tracer", name: str, trace: str,
                 span: str, parent: Optional[str], attrs: Dict):
        self._tracer = tracer
        self.name = name
        self.trace = trace
        self.span = span
        self.parent = parent
        self.attrs = attrs
        self.status = "ok"
        self._t0 = time.perf_counter()

    def __enter__(self) -> "_SpanCtx":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(status="error" if exc_type is not None else self.status)

    def close(self, status: Optional[str] = None) -> None:
        self._tracer.emit(
            "span",
            name=self.name,
            trace=self.trace,
            span=self.span,
            parent=self.parent,
            dur_ms=round((time.perf_counter() - self._t0) * 1e3, 4),
            status=status or self.status,
            **self.attrs,
        )


class Tracer:
    """Ring-buffered JSON-lines event log with an optional file sink."""

    def __init__(self, path: Optional[str] = None, capacity: int = 16384,
                 enabled: bool = True):
        self.enabled = enabled
        self.path = path
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self._fh = None

    def emit(self, ev: str, **fields) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._seq += 1
            rec: Dict[str, object] = {"seq": self._seq,
                                      "ts": round(time.time(), 6),
                                      "ev": ev}
            rec.update(fields)
            self._ring.append(rec)
            if self.path is not None:
                if self._fh is None:
                    self._fh = open(self.path, "a", encoding="utf-8")
                # flush per event: the log must survive kill-injection
                self._fh.write(json.dumps(rec, default=str) + "\n")
                self._fh.flush()

    def span(self, name: str, trace: str, *, parent: Optional[str] = None,
             attempt: int = 0, **attrs) -> _SpanCtx:
        return _SpanCtx(self, name, trace,
                        span_id(trace, name, attempt), parent, attrs)

    def events(self) -> List[Dict]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


_NULL = Tracer(enabled=False)
_tracer: Tracer = _NULL


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install a tracer (or None to disable); returns the previous one."""
    global _tracer
    prev = _tracer
    _tracer = tracer if tracer is not None else _NULL
    return prev


def enable(path: Optional[str] = None, capacity: int = 16384) -> Tracer:
    t = Tracer(path=path, capacity=capacity, enabled=True)
    set_tracer(t)
    return t


def disable() -> None:
    set_tracer(None)


def configure_from_env() -> Tracer:
    """Enable tracing with a file sink when ``REPRO_TRACE_FILE`` is set
    (the CI obs-check hook); otherwise leave the disabled tracer alone."""
    path = os.environ.get(TRACE_FILE_ENV)
    if path:
        return enable(path=path)
    return get_tracer()
