"""Observability layer: metrics registry, span tracing, offline checker.

Import-light by design: ``repro.obs`` depends only on the standard library,
so ``repro.core`` / ``repro.storage`` / ``repro.workflow`` can all import it
without cycles, and the offline checker (``repro.obs.checker``) can replay a
trace with no cluster code on the path.
"""

from .registry import Counter, Gauge, Histogram, QuantileSketch, Registry, Scope
from .trace import (
    TRACE_FILE_ENV,
    Tracer,
    base_uuid,
    configure_from_env,
    disable,
    enable,
    get_tracer,
    set_tracer,
    span_id,
    trace_id,
    txn_trace_id,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "QuantileSketch",
    "Registry",
    "Scope",
    "TRACE_FILE_ENV",
    "Tracer",
    "base_uuid",
    "configure_from_env",
    "disable",
    "enable",
    "get_tracer",
    "set_tracer",
    "span_id",
    "trace_id",
    "txn_trace_id",
]
