"""Unified metrics registry: counters, gauges, histograms with streaming
percentile sketches.

One ``Registry`` instance per scope owner (an ``AftNode``, a
``WorkflowPool``, a ``LambdaPlatform``); components either create typed
metrics (``counter`` / ``gauge`` / ``histogram``) or attach the live stats
dicts they already maintain (``attach_counters`` / ``attach_provider``), so
the pre-existing ``stats["x"] += 1`` call sites keep working while the
registry becomes the single read path.

``snapshot()`` returns a flat, JSON-serializable dict: plain numbers for
counters/gauges, a mergeable summary dict for each histogram.  Snapshots
from many nodes combine with ``Registry.merge`` (counters sum, ``*_rate``
keys average, histogram sketches union by weighted sample) — that is what
the gossip-fed cluster view in ``core/gossip.MetricsPlane`` ships around —
and render to a Prometheus-style text dump with ``Registry.to_prometheus``.

Latency histograms store **milliseconds of wall time**.  Benchmarks run the
engines under a ``time_scale`` compression factor; the registry carries
that factor (``Registry.time_scale``) so report tooling can re-expand
percentiles to engine milliseconds (``wall_ms / time_scale``) without the
hot path paying for the division.
"""

from __future__ import annotations

import re
import threading
import time
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "QuantileSketch",
    "Registry",
    "Scope",
]

_QUANTILES = ((0.50, "p50_ms"), (0.90, "p90_ms"), (0.99, "p99_ms"))


def _weighted_quantile(pairs: List[Tuple[float, float]], q: float) -> float:
    """Quantile over (value, weight) pairs by weighted rank."""
    if not pairs:
        return 0.0
    pairs = sorted(pairs)
    total = sum(w for _, w in pairs)
    rank = q * total
    acc = 0.0
    for value, weight in pairs:
        acc += weight
        if acc >= rank:
            return value
    return pairs[-1][0]


class QuantileSketch:
    """Bounded-memory streaming quantile sketch.

    Keeps at most ``max_samples`` retained values; on overflow it halves the
    retained set (every other sorted sample) and doubles both the per-sample
    weight and the keep-one-in-``weight`` admission stride.  Count / sum /
    min / max stay exact; quantiles degrade gracefully to a weighted
    subsample.  Summaries carry the retained samples so sketches from
    different nodes merge without approximation beyond what each already
    made.
    """

    __slots__ = ("max_samples", "samples", "weight", "count", "total",
                 "vmin", "vmax", "_admit")

    def __init__(self, max_samples: int = 256):
        self.max_samples = max(8, int(max_samples))
        self.samples: List[float] = []
        self.weight = 1
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._admit = 0  # admission phase: record when it hits 0 (mod weight)

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += value
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value
        if self._admit == 0:
            self.samples.append(value)
            if len(self.samples) > self.max_samples:
                self.samples = sorted(self.samples)[::2]
                self.weight *= 2
        self._admit = (self._admit + 1) % self.weight

    def quantile(self, q: float) -> float:
        return _weighted_quantile(
            [(v, float(self.weight)) for v in self.samples], q)

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "count": self.count,
            "sum_ms": round(self.total, 4),
            "min_ms": round(self.vmin, 4) if self.count else 0.0,
            "max_ms": round(self.vmax, 4) if self.count else 0.0,
        }
        pairs = [(v, float(self.weight)) for v in self.samples]
        for q, key in _QUANTILES:
            out[key] = round(_weighted_quantile(pairs, q), 4)
        out["samples"] = [round(v, 4) for v in self.samples]
        out["weight"] = self.weight
        return out

    @staticmethod
    def merge_summaries(summaries: Iterable[Mapping]) -> Dict[str, object]:
        """Combine histogram summary dicts (e.g. one per node)."""
        count = 0
        total = 0.0
        vmin = float("inf")
        vmax = float("-inf")
        pairs: List[Tuple[float, float]] = []
        for s in summaries:
            if not s or not s.get("count"):
                continue
            count += int(s["count"])
            total += float(s.get("sum_ms", 0.0))
            vmin = min(vmin, float(s.get("min_ms", vmin)))
            vmax = max(vmax, float(s.get("max_ms", vmax)))
            w = float(s.get("weight", 1))
            pairs.extend((float(v), w) for v in s.get("samples", ()))
        out: Dict[str, object] = {
            "count": count,
            "sum_ms": round(total, 4),
            "min_ms": round(vmin, 4) if count else 0.0,
            "max_ms": round(vmax, 4) if count else 0.0,
        }
        for q, key in _QUANTILES:
            out[key] = round(_weighted_quantile(pairs, q), 4)
        out["samples"] = [round(v, 4) for v, _ in pairs[:512]]
        out["weight"] = 1
        return out


class Counter:
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-write-wins value, or a zero-arg callback sampled at snapshot."""

    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str):
        self.name = name
        self._value: float = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, value: float) -> None:
        self._value = value

    def set_fn(self, fn: Callable[[], float]) -> None:
        self._fn = fn

    @property
    def value(self) -> float:
        if self._fn is not None:
            return self._fn()
        return self._value


class Histogram:
    """Latency histogram; values are wall-clock milliseconds."""

    __slots__ = ("name", "_sketch", "_lock")

    def __init__(self, name: str, max_samples: int = 256):
        self.name = name
        self._sketch = QuantileSketch(max_samples)
        self._lock = threading.Lock()

    def observe(self, value_ms: float) -> None:
        with self._lock:
            self._sketch.observe(value_ms)

    def observe_s(self, seconds: float) -> None:
        self.observe(seconds * 1e3)

    @property
    def count(self) -> int:
        return self._sketch.count

    def quantile(self, q: float) -> float:
        with self._lock:
            return self._sketch.quantile(q)

    def summary(self) -> Dict[str, object]:
        with self._lock:
            return self._sketch.summary()


class _Timer:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram):
        self._hist = hist
        self._t0 = 0.0

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._hist.observe_s(time.perf_counter() - self._t0)


class Registry:
    """Namespace of metrics plus live views onto legacy stats dicts."""

    def __init__(self, name: str = "", time_scale: float = 1.0):
        self.name = name
        self.time_scale = float(time_scale) if time_scale else 1.0
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}
        self._live: List[Tuple[str, Mapping]] = []
        self._providers: List[Tuple[str, Callable[[], Mapping]]] = []

    # -- typed metrics ------------------------------------------------------

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def observe_site(self, site: str, seconds: float) -> None:
        """Record latency at a named fault-injection site (``invoke:batch``,
        ``pipeline:flush``, ...) under the ``site:`` histogram namespace."""
        self.histogram(f"site:{site}").observe_s(seconds)

    def timer(self, name: str) -> _Timer:
        return _Timer(self.histogram(name))

    # -- live legacy views --------------------------------------------------

    def attach_counters(self, mapping: Mapping, prefix: str = "") -> None:
        """Expose a live counters dict; the owner keeps mutating it and the
        registry reads it at snapshot time (zero hot-path cost)."""
        with self._lock:
            self._live.append((prefix, mapping))

    def attach_provider(self, fn: Callable[[], Mapping],
                        prefix: str = "") -> None:
        """Expose derived gauges computed by ``fn()`` at snapshot time."""
        with self._lock:
            self._providers.append((prefix, fn))

    def scoped(self, prefix: str) -> "Scope":
        return Scope(self, prefix)

    # -- snapshot / merge / export ------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            live = list(self._live)
            providers = list(self._providers)
            metrics = list(self._metrics.items())
        out: Dict[str, object] = {}
        for prefix, mapping in live:
            for k, v in dict(mapping).items():
                out[prefix + k] = v
        for prefix, fn in providers:
            for k, v in dict(fn()).items():
                out[prefix + k] = v
        for name, m in metrics:
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out

    @staticmethod
    def merge(snapshots: Iterable[Mapping]) -> Dict[str, object]:
        """Cluster-merge per-node snapshots: histogram summaries union by
        weighted sample, ``*_rate`` keys average, everything else sums."""
        hists: Dict[str, List[Mapping]] = {}
        sums: Dict[str, float] = {}
        rates: Dict[str, List[float]] = {}
        for snap in snapshots:
            for k, v in snap.items():
                if isinstance(v, Mapping):
                    hists.setdefault(k, []).append(v)
                elif isinstance(v, (int, float)):
                    if k.endswith("_rate"):
                        rates.setdefault(k, []).append(float(v))
                    else:
                        sums[k] = sums.get(k, 0) + v
        out: Dict[str, object] = dict(sums)
        for k, vals in rates.items():
            out[k] = round(sum(vals) / len(vals), 4) if vals else 0.0
        for k, summaries in hists.items():
            out[k] = QuantileSketch.merge_summaries(summaries)
        return out

    @staticmethod
    def to_prometheus(snapshot: Mapping, prefix: str = "aft",
                      labels: Optional[Mapping[str, str]] = None) -> str:
        """Render a snapshot as Prometheus exposition-format text."""
        label_s = ""
        pairs = sorted((labels or {}).items())
        if pairs:
            label_s = "{%s}" % ",".join(f'{k}="{v}"' for k, v in pairs)

        def metric_name(key: str) -> str:
            return f"{prefix}_{re.sub(r'[^a-zA-Z0-9_]', '_', key)}"

        lines: List[str] = []
        for key in sorted(snapshot):
            value = snapshot[key]
            name = metric_name(key)
            if isinstance(value, Mapping):
                lines.append(f"{name}_count{label_s} {value.get('count', 0)}")
                lines.append(
                    f"{name}_sum_ms{label_s} {value.get('sum_ms', 0.0)}")
                for q, qkey in _QUANTILES:
                    if qkey in value:
                        if pairs:
                            q_label = "{%s}" % ",".join(
                                [f'{k}="{v}"' for k, v in pairs]
                                + [f'quantile="{q}"'])
                        else:
                            q_label = '{quantile="%s"}' % q
                        lines.append(f"{name}{q_label} {value[qkey]}")
            elif isinstance(value, bool):
                lines.append(f"{name}{label_s} {int(value)}")
            elif isinstance(value, (int, float)):
                lines.append(f"{name}{label_s} {value}")
        return "\n".join(lines) + "\n"


class Scope:
    """Dotted-prefix view onto a parent registry; nests via ``scoped()``."""

    __slots__ = ("registry", "prefix")

    def __init__(self, registry: Registry, prefix: str):
        self.registry = registry
        self.prefix = prefix

    def _join(self, name: str) -> str:
        return f"{self.prefix}.{name}" if self.prefix else name

    def counter(self, name: str) -> Counter:
        return self.registry.counter(self._join(name))

    def gauge(self, name: str) -> Gauge:
        return self.registry.gauge(self._join(name))

    def histogram(self, name: str) -> Histogram:
        return self.registry.histogram(self._join(name))

    def timer(self, name: str) -> _Timer:
        return self.registry.timer(self._join(name))

    def observe_site(self, site: str, seconds: float) -> None:
        self.registry.observe_site(site, seconds)

    def attach_counters(self, mapping: Mapping, prefix: str = "") -> None:
        self.registry.attach_counters(mapping, self._join(prefix) + "."
                                      if prefix else self.prefix + ".")

    def attach_provider(self, fn: Callable[[], Mapping],
                        prefix: str = "") -> None:
        self.registry.attach_provider(fn, self._join(prefix) + "."
                                      if prefix else self.prefix + ".")

    def scoped(self, prefix: str) -> "Scope":
        return Scope(self.registry, self._join(prefix))
