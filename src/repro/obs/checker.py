"""Offline trace-replay invariant checker.

Re-derives AFT's safety invariants from a JSON-lines trace event log alone —
no access to the cluster, the storage engine, or ``repro.core`` (this module
is deliberately self-contained, a *separate encoding* of the invariants so it
can catch protocol bugs rather than inherit them):

* **read atomicity** (Definition 1, §3.4) — from ``read`` events: a
  transaction that read ``k`` at version ``i`` whose committing transaction
  cowrote ``l`` must not also have read ``l`` at a version older than ``i``
  (and must not have read ``l`` as NULL while ``i`` proves a committed
  version of ``l`` exists).
* **§3.3 write ordering** — from ``order`` events: for every committing
  transaction, data/version writes land before the commit record, and the
  commit record lands before the commit becomes locally visible.
* **exactly-once triggers/commits** (§3.3.1) — from ``wf_finished`` events:
  all non-deduplicated completions of one workflow UUID (including chain
  children replayed after a kill) must agree on a single committed
  transaction ID — two distinct TIDs means the idempotency machinery
  re-applied effects.
* **span uniqueness** — from ``span`` events: no span ID is emitted twice
  (attempt-qualified IDs must make kill-and-retry replays distinct).

Versions are compared by their encoded TxnId strings, whose lexicographic
order equals ``⟨timestamp, uuid⟩`` order (see ``core/ids.py``).

CLI::

    python -m repro.obs.checker trace.jsonl        # exit 1 on any violation
    python -m repro.obs.checker --selftest         # seeded-violation check
"""

from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Set, Tuple

__all__ = [
    "Violation",
    "CheckResult",
    "check_events",
    "check_file",
    "seeded_violation_events",
]


@dataclass
class Violation:
    invariant: str   # read-atomicity | write-ordering | exactly-once | span-unique
    detail: str

    def __str__(self) -> str:
        return f"[{self.invariant}] {self.detail}"


@dataclass
class CheckResult:
    violations: List[Violation] = field(default_factory=list)
    events: int = 0
    txns_checked: int = 0
    commits_checked: int = 0
    finishes_checked: int = 0
    spans_checked: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        lines = [
            f"events scanned:        {self.events}",
            f"read txns checked:     {self.txns_checked}",
            f"commit orders checked: {self.commits_checked}",
            f"workflow finishes:     {self.finishes_checked}",
            f"spans checked:         {self.spans_checked}",
            f"violations:            {len(self.violations)}",
        ]
        lines.extend(f"  {v}" for v in self.violations)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# invariant 1: read atomicity (Definition 1)
# ---------------------------------------------------------------------------

def _fractured_witness(version: Mapping[str, str],
                       cow: Mapping[str, Tuple[str, ...]]) -> Optional[str]:
    """Definition 1 over encoded-TxnId strings: ∀ k read at version i, every
    key l cowritten by i's transaction that was also read must satisfy
    j ≥ i.  (NULL reads are excluded, mirroring Algorithm 1's dynamic read
    sets: a key read as NULL before a cowriting sibling entered the read set
    is a legitimately stale-but-atomic read, not a fracture.)"""
    for k, i in version.items():
        for l in cow.get(k, ()):
            j = version.get(l)
            if j is not None and j < i:  # encoded TxnIds order lexically
                return (f"read {k}@{i} whose txn cowrote {l}, but read "
                        f"{l}@{j} with {j} < {i}")
    return None


def _check_read_atomicity(reads_by_txn: Mapping[str, List[dict]],
                          out: CheckResult) -> None:
    for txn, reads in reads_by_txn.items():
        out.txns_checked += 1
        version: Dict[str, str] = {}      # key -> encoded tid (last read wins)
        cow: Dict[str, Tuple[str, ...]] = {}
        for r in reads:
            key = r.get("key")
            tid = r.get("tid")
            if key is None or tid is None:
                continue
            version[key] = str(tid)
            cow[key] = tuple(str(c) for c in (r.get("cow") or ()))
            witness = _fractured_witness(version, cow)
            if witness is not None:
                out.violations.append(Violation(
                    "read-atomicity", f"txn {txn}: {witness}"))
                # drop the offending read so one stale read is not re-counted
                # on every subsequent read of the same transaction
                del version[key]
                del cow[key]


# ---------------------------------------------------------------------------
# invariant 2: §3.3 write ordering
# ---------------------------------------------------------------------------

def _check_write_ordering(orders_by_uuid: Mapping[str, List[dict]],
                          out: CheckResult) -> None:
    for uuid, evs in orders_by_uuid.items():
        out.commits_checked += 1
        version_seqs = [e["seq"] for e in evs if e["stage"] == "versions"]
        record_evs = [e for e in evs if e["stage"] == "record"]
        record_seqs = [e["seq"] for e in record_evs]
        for e in record_evs:
            if e.get("writes", 0) > 0 and not any(
                    s < e["seq"] for s in version_seqs):
                out.violations.append(Violation(
                    "write-ordering",
                    f"txn {uuid}: commit record (seq {e['seq']}) with "
                    f"{e['writes']} writes but no prior version flush"))
        for e in (e for e in evs if e["stage"] == "visible"):
            if not any(s < e["seq"] for s in record_seqs):
                out.violations.append(Violation(
                    "write-ordering",
                    f"txn {uuid}: became visible (seq {e['seq']}) before "
                    f"any commit-record write"))


# ---------------------------------------------------------------------------
# invariant 3: exactly-once workflow completion (§3.3.1)
# ---------------------------------------------------------------------------

def _check_exactly_once(finishes_by_uuid: Mapping[str, List[dict]],
                        out: CheckResult) -> None:
    for uuid, evs in finishes_by_uuid.items():
        out.finishes_checked += 1
        tids: Set[str] = {
            str(e["tid"]) for e in evs
            if not e.get("deduped") and e.get("tid") is not None
        }
        if len(tids) > 1:
            out.violations.append(Violation(
                "exactly-once",
                f"workflow {uuid}: finished under {len(tids)} distinct "
                f"commit TIDs ({sorted(tids)}) — effects applied twice"))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def check_events(events: Iterable[Mapping]) -> CheckResult:
    out = CheckResult()
    reads_by_txn: Dict[str, List[dict]] = {}
    orders_by_uuid: Dict[str, List[dict]] = {}
    finishes_by_uuid: Dict[str, List[dict]] = {}
    span_ids: Dict[str, int] = {}

    for ev in events:
        out.events += 1
        kind = ev.get("ev")
        if kind == "read":
            reads_by_txn.setdefault(str(ev.get("txn")), []).append(dict(ev))
        elif kind == "order":
            orders_by_uuid.setdefault(str(ev.get("uuid")), []).append(dict(ev))
        elif kind == "wf_finished":
            finishes_by_uuid.setdefault(
                str(ev.get("uuid")), []).append(dict(ev))
        elif kind == "span":
            out.spans_checked += 1
            sid = ev.get("span")
            if sid is not None:
                span_ids[sid] = span_ids.get(sid, 0) + 1

    _check_read_atomicity(reads_by_txn, out)
    _check_write_ordering(orders_by_uuid, out)
    _check_exactly_once(finishes_by_uuid, out)
    for sid, n in span_ids.items():
        if n > 1:
            out.violations.append(Violation(
                "span-unique", f"span id {sid} emitted {n} times"))
    return out


def check_file(path: str) -> CheckResult:
    events = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return check_events(events)


# ---------------------------------------------------------------------------
# seeded violation (negative self-test)
# ---------------------------------------------------------------------------

def seeded_violation_events() -> List[dict]:
    """A minimal trace with one deliberate read-atomicity violation: txn B
    reads y from t1 (which cowrote x and y) but x from the older t0."""
    t0 = f"{1000:020d}.aaaa"
    t1 = f"{2000:020d}.bbbb"
    return [
        {"seq": 1, "ev": "order", "uuid": "bbbb", "stage": "versions"},
        {"seq": 2, "ev": "order", "uuid": "bbbb", "stage": "record",
         "writes": 2},
        {"seq": 3, "ev": "order", "uuid": "bbbb", "stage": "visible"},
        {"seq": 4, "ev": "read", "txn": "reader", "key": "x", "tid": t0,
         "cow": ["x"]},
        {"seq": 5, "ev": "read", "txn": "reader", "key": "y", "tid": t1,
         "cow": ["x", "y"]},
    ]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.checker",
        description="Replay a trace event log and verify AFT invariants.")
    ap.add_argument("trace", nargs="?", help="JSON-lines trace file")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the checker flags a seeded violation")
    args = ap.parse_args(argv)

    if args.selftest:
        res = check_events(seeded_violation_events())
        detected = any(v.invariant == "read-atomicity"
                       for v in res.violations)
        print(res.summary())
        print("selftest:", "seeded violation detected"
              if detected else "FAILED to detect seeded violation")
        return 0 if detected else 1

    if not args.trace:
        ap.error("a trace file is required (or --selftest)")
    res = check_file(args.trace)
    print(res.summary())
    return 0 if res.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
