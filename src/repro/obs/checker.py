"""Offline trace-replay invariant checker.

Re-derives AFT's safety invariants from a JSON-lines trace event log alone —
no access to the cluster, the storage engine, or ``repro.core`` (this module
is deliberately self-contained, a *separate encoding* of the invariants so it
can catch protocol bugs rather than inherit them):

* **read atomicity** (Definition 1, §3.4) — from ``read`` events: a
  transaction that read ``k`` at version ``i`` whose committing transaction
  cowrote ``l`` must not also have read ``l`` at a version older than ``i``
  (and must not have read ``l`` as NULL while ``i`` proves a committed
  version of ``l`` exists).
* **§3.3 write ordering** — from ``order`` events: for every committing
  transaction, data/version writes land before the commit record, and the
  commit record lands before the commit becomes locally visible.
* **exactly-once triggers/commits** (§3.3.1) — from ``wf_finished`` events:
  all non-deduplicated completions of one workflow UUID (including chain
  children replayed after a kill) must agree on a single committed
  transaction ID — two distinct TIDs means the idempotency machinery
  re-applied effects.
* **span uniqueness** — from ``span`` events: no span ID is emitted twice
  (attempt-qualified IDs must make kill-and-retry replays distinct).
* **read durability** (gossip-fed fast path, §4) — from ``read`` + ``order``
  events: a read that resolved to version ``tid`` must be sequenced *after*
  that transaction's commit record was durably written.  The multicast
  plane pushes commit metadata ahead of storage probes; if a cache entry
  ever let a reader observe a version before its record landed, a crash
  could revoke the version after it was served.
* **snapshot bound** (bounded-staleness snapshot reads) — from ``snap``
  events: a served snapshot read must (a) land within its declared
  staleness bound, (b) never return a version *newer* than its watermark,
  and (c) never *miss* a version committed at or below the watermark
  before the read (the watermark is a promise of completeness up to it).
  Commit ``order`` events that carry ``tid``/``keys`` metadata feed (c);
  older traces without those fields simply skip it.
* **refresh correlation** (serving-lane weight swaps) — from ``span``
  events named ``weight_refresh``: a replica's swap carries the publishing
  transaction's UUID; when that publish's order events are in the trace,
  the swap must be sequenced *after* the publish's commit record.  A swap
  before durability means the replica served weights a crash could still
  revoke.  Publishes absent from the trace (committed before tracing
  began) are skipped — the invariant binds only when both sides are
  observable.

Versions are compared by their encoded TxnId strings, whose lexicographic
order equals ``⟨timestamp, uuid⟩`` order (see ``core/ids.py``).

CLI::

    python -m repro.obs.checker trace.jsonl        # exit 1 on any violation
    python -m repro.obs.checker --selftest         # seeded-violation check
"""

from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Set, Tuple

__all__ = [
    "Violation",
    "CheckResult",
    "check_events",
    "check_file",
    "seeded_violation_events",
]


@dataclass
class Violation:
    # read-atomicity | write-ordering | exactly-once | span-unique
    # | read-durability | snapshot-bound | refresh-correlation
    invariant: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.invariant}] {self.detail}"


@dataclass
class CheckResult:
    violations: List[Violation] = field(default_factory=list)
    events: int = 0
    txns_checked: int = 0
    commits_checked: int = 0
    finishes_checked: int = 0
    spans_checked: int = 0
    snaps_checked: int = 0
    refreshes_checked: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        lines = [
            f"events scanned:        {self.events}",
            f"read txns checked:     {self.txns_checked}",
            f"commit orders checked: {self.commits_checked}",
            f"workflow finishes:     {self.finishes_checked}",
            f"spans checked:         {self.spans_checked}",
            f"snapshot reads:        {self.snaps_checked}",
            f"weight refreshes:      {self.refreshes_checked}",
            f"violations:            {len(self.violations)}",
        ]
        lines.extend(f"  {v}" for v in self.violations)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# invariant 1: read atomicity (Definition 1)
# ---------------------------------------------------------------------------

def _fractured_witness(version: Mapping[str, str],
                       cow: Mapping[str, Tuple[str, ...]]) -> Optional[str]:
    """Definition 1 over encoded-TxnId strings: ∀ k read at version i, every
    key l cowritten by i's transaction that was also read must satisfy
    j ≥ i.  (NULL reads are excluded, mirroring Algorithm 1's dynamic read
    sets: a key read as NULL before a cowriting sibling entered the read set
    is a legitimately stale-but-atomic read, not a fracture.)"""
    for k, i in version.items():
        for l in cow.get(k, ()):
            j = version.get(l)
            if j is not None and j < i:  # encoded TxnIds order lexically
                return (f"read {k}@{i} whose txn cowrote {l}, but read "
                        f"{l}@{j} with {j} < {i}")
    return None


def _check_read_atomicity(reads_by_txn: Mapping[str, List[dict]],
                          out: CheckResult) -> None:
    for txn, reads in reads_by_txn.items():
        out.txns_checked += 1
        version: Dict[str, str] = {}      # key -> encoded tid (last read wins)
        cow: Dict[str, Tuple[str, ...]] = {}
        for r in reads:
            key = r.get("key")
            tid = r.get("tid")
            if key is None or tid is None:
                continue
            version[key] = str(tid)
            cow[key] = tuple(str(c) for c in (r.get("cow") or ()))
            witness = _fractured_witness(version, cow)
            if witness is not None:
                out.violations.append(Violation(
                    "read-atomicity", f"txn {txn}: {witness}"))
                # drop the offending read so one stale read is not re-counted
                # on every subsequent read of the same transaction
                del version[key]
                del cow[key]


# ---------------------------------------------------------------------------
# invariant 2: §3.3 write ordering
# ---------------------------------------------------------------------------

def _check_write_ordering(orders_by_uuid: Mapping[str, List[dict]],
                          out: CheckResult) -> None:
    for uuid, evs in orders_by_uuid.items():
        out.commits_checked += 1
        version_seqs = [e["seq"] for e in evs if e["stage"] == "versions"]
        record_evs = [e for e in evs if e["stage"] == "record"]
        record_seqs = [e["seq"] for e in record_evs]
        for e in record_evs:
            if e.get("writes", 0) > 0 and not any(
                    s < e["seq"] for s in version_seqs):
                out.violations.append(Violation(
                    "write-ordering",
                    f"txn {uuid}: commit record (seq {e['seq']}) with "
                    f"{e['writes']} writes but no prior version flush"))
        for e in (e for e in evs if e["stage"] == "visible"):
            if not any(s < e["seq"] for s in record_seqs):
                out.violations.append(Violation(
                    "write-ordering",
                    f"txn {uuid}: became visible (seq {e['seq']}) before "
                    f"any commit-record write"))


# ---------------------------------------------------------------------------
# invariant 3: exactly-once workflow completion (§3.3.1)
# ---------------------------------------------------------------------------

def _check_exactly_once(finishes_by_uuid: Mapping[str, List[dict]],
                        out: CheckResult) -> None:
    for uuid, evs in finishes_by_uuid.items():
        out.finishes_checked += 1
        tids: Set[str] = {
            str(e["tid"]) for e in evs
            if not e.get("deduped") and e.get("tid") is not None
        }
        if len(tids) > 1:
            out.violations.append(Violation(
                "exactly-once",
                f"workflow {uuid}: finished under {len(tids)} distinct "
                f"commit TIDs ({sorted(tids)}) — effects applied twice"))


# ---------------------------------------------------------------------------
# invariant 4: read durability (gossip-fed fast path)
# ---------------------------------------------------------------------------

def _tid_ts(encoded: str) -> Optional[int]:
    """Timestamp component of an encoded TxnId, or None if unparsable."""
    head, _, _ = str(encoded).partition(".")
    try:
        return int(head)
    except ValueError:
        return None


def _tid_uuid(encoded: str) -> Optional[str]:
    enc = str(encoded)
    if "." not in enc:
        return None
    return enc.split(".", 1)[1]


def _check_read_durability(reads_by_txn: Mapping[str, List[dict]],
                           orders_by_uuid: Mapping[str, List[dict]],
                           out: CheckResult) -> None:
    """A read resolving to ``tid`` must be sequenced after that commit's
    record write.  Transactions whose order events are absent from the
    trace (e.g. committed before tracing started) are skipped — the
    invariant only binds when both sides are observable."""
    min_record_seq: Dict[str, int] = {}
    for uuid, evs in orders_by_uuid.items():
        seqs = [e["seq"] for e in evs if e.get("stage") == "record"]
        if seqs:
            min_record_seq[uuid] = min(seqs)
    for txn, reads in reads_by_txn.items():
        for r in reads:
            tid = r.get("tid")
            seq = r.get("seq")
            if tid is None or seq is None:
                continue
            uuid = _tid_uuid(tid)
            if uuid is None:
                continue
            rec = min_record_seq.get(uuid)
            if rec is not None and seq < rec:
                out.violations.append(Violation(
                    "read-durability",
                    f"txn {txn}: read {r.get('key')}@{tid} (seq {seq}) "
                    f"before its commit record landed (seq {rec}) — the "
                    f"version was not durable when served"))


# ---------------------------------------------------------------------------
# invariant 5: bounded-staleness snapshot reads
# ---------------------------------------------------------------------------

def _check_snapshot_bounds(snaps: List[dict],
                           orders_by_uuid: Mapping[str, List[dict]],
                           out: CheckResult) -> None:
    """Three obligations per served ``snap`` event: the lag the node
    reported must fit the caller's bound; the returned version must not be
    newer than the watermark; and no version committed at or below the
    watermark (whose record landed before the read) may be missed."""
    committed: List[Tuple[str, int, str, int]] = []  # key, ts, tid, rec seq
    for evs in orders_by_uuid.values():
        for e in evs:
            if e.get("stage") != "record":
                continue
            tid, keys = e.get("tid"), e.get("keys")
            if tid is None or not keys:
                continue  # pre-fast-path trace: no snapshot metadata
            ts = _tid_ts(tid)
            if ts is None:
                continue
            committed.extend((str(k), ts, str(tid), e["seq"]) for k in keys)

    for s in snaps:
        out.snaps_checked += 1
        key, wm, seq = s.get("key"), s.get("wm"), s.get("seq")
        if wm is None or seq is None:
            continue
        lag, bound = s.get("lag_ns"), s.get("bound_ns")
        if lag is not None and bound is not None and lag > bound:
            out.violations.append(Violation(
                "snapshot-bound",
                f"snapshot read of {key} served with lag {lag}ns beyond "
                f"its declared staleness bound {bound}ns"))
        tid = s.get("tid")
        rts = _tid_ts(tid) if tid is not None else None
        if rts is not None and rts > wm:
            out.violations.append(Violation(
                "snapshot-bound",
                f"snapshot read of {key} returned {tid} (ts {rts}) above "
                f"its watermark {wm}"))
            continue
        newest: Optional[Tuple[int, str]] = None
        for k, ts, ctid, rec_seq in committed:
            if k != key or ts > wm or rec_seq >= seq:
                continue
            if newest is None or ts > newest[0]:
                newest = (ts, ctid)
        if newest is not None and (rts is None or rts < newest[0]):
            out.violations.append(Violation(
                "snapshot-bound",
                f"snapshot read of {key} at watermark {wm} returned "
                f"{tid or 'NULL'} but {newest[1]} (ts {newest[0]}) was "
                f"committed within the bound — a covered version was "
                f"missed"))


# ---------------------------------------------------------------------------
# invariant 6: weight-refresh ↔ publish correlation (serving lane)
# ---------------------------------------------------------------------------

def _check_refresh_correlation(refreshes: List[dict],
                               orders_by_uuid: Mapping[str, List[dict]],
                               out: CheckResult) -> None:
    """A ``weight_refresh`` span carrying ``publish_uuid`` must be
    sequenced after that publish's commit record whenever the publish's
    order events are in the trace."""
    for ev in refreshes:
        out.refreshes_checked += 1
        uuid = ev.get("publish_uuid")
        seq = ev.get("seq")
        if uuid is None or seq is None:
            continue
        orders = orders_by_uuid.get(str(uuid))
        if not orders:
            continue  # publish committed before tracing began
        record_seqs = [e["seq"] for e in orders if e.get("stage") == "record"]
        if not record_seqs or min(record_seqs) > seq:
            out.violations.append(Violation(
                "refresh-correlation",
                f"replica {ev.get('engine', '?')} swapped to step "
                f"{ev.get('step', '?')} (seq {seq}) before publish {uuid} "
                f"wrote its commit record — the weights were not durable"))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def check_events(events: Iterable[Mapping]) -> CheckResult:
    out = CheckResult()
    reads_by_txn: Dict[str, List[dict]] = {}
    orders_by_uuid: Dict[str, List[dict]] = {}
    finishes_by_uuid: Dict[str, List[dict]] = {}
    span_ids: Dict[str, int] = {}
    snaps: List[dict] = []
    refreshes: List[dict] = []

    for ev in events:
        out.events += 1
        kind = ev.get("ev")
        if kind == "read":
            reads_by_txn.setdefault(str(ev.get("txn")), []).append(dict(ev))
        elif kind == "order":
            orders_by_uuid.setdefault(str(ev.get("uuid")), []).append(dict(ev))
        elif kind == "wf_finished":
            finishes_by_uuid.setdefault(
                str(ev.get("uuid")), []).append(dict(ev))
        elif kind == "span":
            out.spans_checked += 1
            sid = ev.get("span")
            if sid is not None:
                span_ids[sid] = span_ids.get(sid, 0) + 1
            if ev.get("name") == "weight_refresh":
                refreshes.append(dict(ev))
        elif kind == "snap":
            snaps.append(dict(ev))

    _check_read_atomicity(reads_by_txn, out)
    _check_write_ordering(orders_by_uuid, out)
    _check_exactly_once(finishes_by_uuid, out)
    _check_read_durability(reads_by_txn, orders_by_uuid, out)
    _check_snapshot_bounds(snaps, orders_by_uuid, out)
    _check_refresh_correlation(refreshes, orders_by_uuid, out)
    for sid, n in span_ids.items():
        if n > 1:
            out.violations.append(Violation(
                "span-unique", f"span id {sid} emitted {n} times"))
    return out


def check_file(path: str) -> CheckResult:
    events = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return check_events(events)


# ---------------------------------------------------------------------------
# seeded violation (negative self-test)
# ---------------------------------------------------------------------------

SEED_KINDS = ("read-atomicity", "read-durability", "snapshot-bound",
              "refresh-correlation")


def seeded_violation_events(kind: str = "read-atomicity") -> List[dict]:
    """A minimal trace with exactly one deliberate violation of ``kind``.

    ``read-atomicity`` (the default): txn B reads y from t1 (which cowrote
    x and y) but x from the older t0.  ``read-durability``: a read resolves
    to a version whose commit record lands only *after* the read.
    ``snapshot-bound``: a snapshot read whose watermark covers ts 2000
    returns the ts-1000 version, missing a covered commit.
    ``refresh-correlation``: a replica swaps to a published weight set
    before the publish's commit record lands."""
    if kind == "read-atomicity":
        t0 = f"{1000:020d}.aaaa"
        t1 = f"{2000:020d}.bbbb"
        return [
            {"seq": 1, "ev": "order", "uuid": "bbbb", "stage": "versions"},
            {"seq": 2, "ev": "order", "uuid": "bbbb", "stage": "record",
             "writes": 2},
            {"seq": 3, "ev": "order", "uuid": "bbbb", "stage": "visible"},
            {"seq": 4, "ev": "read", "txn": "reader", "key": "x", "tid": t0,
             "cow": ["x"]},
            {"seq": 5, "ev": "read", "txn": "reader", "key": "y", "tid": t1,
             "cow": ["x", "y"]},
        ]
    if kind == "read-durability":
        t = f"{1500:020d}.cccc"
        return [
            {"seq": 1, "ev": "order", "uuid": "cccc", "stage": "versions"},
            # the read lands BEFORE the commit record: a gossip cache entry
            # served a version that was not yet durable
            {"seq": 2, "ev": "read", "txn": "reader", "key": "x", "tid": t,
             "cow": ["x"]},
            {"seq": 3, "ev": "order", "uuid": "cccc", "stage": "record",
             "writes": 1},
            {"seq": 4, "ev": "order", "uuid": "cccc", "stage": "visible"},
        ]
    if kind == "snapshot-bound":
        t0 = f"{1000:020d}.aaaa"
        t1 = f"{2000:020d}.bbbb"
        return [
            {"seq": 1, "ev": "order", "uuid": "aaaa", "stage": "versions"},
            {"seq": 2, "ev": "order", "uuid": "aaaa", "stage": "record",
             "writes": 1, "tid": t0, "keys": ["x"]},
            {"seq": 3, "ev": "order", "uuid": "aaaa", "stage": "visible"},
            {"seq": 4, "ev": "order", "uuid": "bbbb", "stage": "versions"},
            {"seq": 5, "ev": "order", "uuid": "bbbb", "stage": "record",
             "writes": 1, "tid": t1, "keys": ["x"]},
            {"seq": 6, "ev": "order", "uuid": "bbbb", "stage": "visible"},
            # the watermark (2500) covers t1 (ts 2000), yet the snapshot
            # returned the older t0 — a covered version was missed
            {"seq": 7, "ev": "snap", "key": "x", "tid": t0, "wm": 2500,
             "lag_ns": 0, "bound_ns": 10_000_000_000},
        ]
    if kind == "refresh-correlation":
        return [
            # the swap is sequenced BEFORE the publish's commit record:
            # the replica served weights that were not yet durable
            {"seq": 1, "ev": "span", "name": "weight_refresh",
             "trace": "t" * 16, "span": "tttttttttttttttt/weight_refresh#r0@2",
             "publish_uuid": "publish.r0.2", "step": 2, "engine": "r0"},
            {"seq": 2, "ev": "order", "uuid": "publish.r0.2",
             "stage": "versions"},
            {"seq": 3, "ev": "order", "uuid": "publish.r0.2",
             "stage": "record", "writes": 3},
            {"seq": 4, "ev": "order", "uuid": "publish.r0.2",
             "stage": "visible"},
        ]
    raise ValueError(f"unknown seed kind {kind!r}; one of {SEED_KINDS}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.checker",
        description="Replay a trace event log and verify AFT invariants.")
    ap.add_argument("trace", nargs="?", help="JSON-lines trace file")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the checker flags a seeded violation")
    args = ap.parse_args(argv)

    if args.selftest:
        all_detected = True
        for kind in SEED_KINDS:
            res = check_events(seeded_violation_events(kind))
            detected = [v.invariant for v in res.violations] == [kind]
            all_detected = all_detected and detected
            print(f"-- seed: {kind}")
            print(res.summary())
            print("selftest:", "seeded violation detected"
                  if detected else "FAILED to detect seeded violation")
        return 0 if all_detected else 1

    if not args.trace:
        ap.error("a trace file is required (or --selftest)")
    res = check_file(args.trace)
    print(res.summary())
    return 0 if res.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
