"""Mamba-2 (SSD — state-space dual) blocks.

Training/prefill uses the chunked SSD algorithm (Mamba-2 paper, Listing 1):
intra-chunk quadratic attention-like term + inter-chunk linear recurrence
over chunk states, with the inter-chunk scan instrumented for roofline
accounting.  Decode is the O(1)-per-token state update.  The Pallas kernel
(``repro.kernels.ssd_scan``) replaces the chunked reference on TPU.

Shapes follow the paper: ``x`` split into heads (H, P=head_dim); scalar decay
``A`` per head; shared ``B``/``C`` of state size N (single group).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .params import ParamDef
from .scan import instrumented_scan
from .sharding import Ax, constrain


def mamba2_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, dt = cfg.d_model, cfg.dtype
    di = cfg.ssm_d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    conv = cfg.ssm_conv
    # in_proj emits [z (gate), x, B, C, dt]
    zxbcdt = 2 * di + 2 * n + h
    return {
        "in_proj": ParamDef((d, zxbcdt), ("embed", "mlp"), dt),
        "conv_w": ParamDef((conv, di + 2 * n), ("conv", "mlp"), dt, scale=0.5),
        "conv_b": ParamDef((di + 2 * n,), ("mlp",), dt, init="zeros"),
        "a_log": ParamDef((h,), ("ssm_heads",), "float32", init="zeros"),
        "d_skip": ParamDef((h,), ("ssm_heads",), "float32", init="ones"),
        "dt_bias": ParamDef((h,), ("ssm_heads",), "float32", init="zeros"),
        "norm": ParamDef((di,), ("mlp",), dt, init="ones"),
        "out_proj": ParamDef((di, d), ("mlp", "embed"), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad,
        w[:, None, :],  # (K, 1, C)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum x[..., j+1..i] (i ≥ j)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P) pre-scaled inputs
    dt: jax.Array,     # (B, S, H)    softplus'd timestep
    a: jax.Array,      # (H,)         negative decay rate
    b_mat: jax.Array,  # (B, S, N)
    c_mat: jax.Array,  # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD: returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk

    x = x.astype(jnp.float32)
    da = dt * a[None, None, :]                     # (B, S, H) per-step log decay
    xdt = x * dt[..., None]                        # input scaled by Δt

    def split(t):  # (B, S, ...) -> (NC, B, chunk, ...)
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dac, bc, cc = split(xdt), split(da), split(b_mat), split(c_mat)

    # ---- intra-chunk (quadratic within chunk, parallel over chunks) -------
    # index legend: c=chunk idx, b=batch, q/k=positions, h=heads, p=head dim,
    # j=state dim
    lmat = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))   # (NC,B,H,chunk,chunk)
    scores = jnp.einsum("cbqj,cbkj->cbqk", cc, bc)       # (NC,B,chunk,chunk)
    y_intra = jnp.einsum(
        "cbhqk,cbqk,cbkhp->cbqhp", lmat, scores, xc
    )

    # ---- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(
        jnp.cumsum(dac, axis=2)[:, :, -1:, :] - jnp.cumsum(dac, axis=2)
    )  # (NC,B,chunk,H): exp(sum_{r>t} da_r)
    states = jnp.einsum("cbkj,cbkh,cbkhp->cbhpj", bc, decay_to_end, xc)

    # ---- inter-chunk recurrence (instrumented scan) ------------------------
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))          # (NC,B,H)
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )

    def body(carry, inp):
        state = carry
        new_state, decay = inp
        out_state = state  # state *entering* the chunk
        state = state * decay[..., None, None] + new_state
        return state, out_state

    st_ax = Ax(("batch", "ssm_heads", None, None))
    final_state, entry_states = instrumented_scan(
        body, h0, (states, chunk_decay), name="ssd_interchunk",
        logical_axes=(st_ax, (st_ax, Ax(("batch", "ssm_heads")))),
    )

    # ---- inter-chunk contribution ------------------------------------------
    decay_from_start = jnp.exp(jnp.cumsum(dac, axis=2))   # (NC,B,chunk,H)
    y_inter = jnp.einsum(
        "cbqj,cbqh,cbhpj->cbqhp", cc, decay_from_start, entry_states
    )

    y = (y_intra + y_inter).swapaxes(0, 1).reshape(bsz, s, h, p)
    return y, final_state


def mamba2_forward(
    params: Dict[str, jax.Array],
    xin: jax.Array,    # (B, S, d_model)
    cfg: ArchConfig,
) -> jax.Array:
    """Full-sequence Mamba-2 block (training / prefill)."""
    y, _ = mamba2_sequence(params, xin, cfg, init_state=None)
    return y


def mamba2_sequence(
    params: Dict[str, jax.Array],
    xin: jax.Array,
    cfg: ArchConfig,
    init_state: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    bsz, s, _ = xin.shape
    di, h, n, p = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", xin, params["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    x, b_mat, c_mat = jnp.split(xbc, [di, di + n], axis=-1)
    x = constrain(x, "batch", "seq", "mlp")
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"]
    )  # (B, S, H)
    a = -jnp.exp(params["a_log"])  # (H,) negative
    xh = x.reshape(bsz, s, h, p)
    if cfg.use_pallas and init_state is None:
        from repro.kernels.ops import ssd as pallas_ssd

        y, state = pallas_ssd(
            (xh * dt[..., None]).astype(jnp.float32),
            dt * a[None, None, :],
            b_mat.astype(jnp.float32), c_mat.astype(jnp.float32),
            chunk=cfg.ssm_chunk,
        )
    else:
        y, state = ssd_chunked(
            xh, dt, a, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32),
            cfg.ssm_chunk, init_state,
        )
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(xin.dtype)
    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(
        xin.dtype
    ) * params["norm"]
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return constrain(out, "batch", "seq", "embed"), state


# ---------------------------------------------------------------------------
# decode: O(1) per-token state update
# ---------------------------------------------------------------------------

def mamba2_decode_step(
    params: Dict[str, jax.Array],
    xin: jax.Array,            # (B, 1, d_model)
    conv_state: jax.Array,     # (B, K-1, di + 2N) trailing inputs
    ssm_state: jax.Array,      # (B, H, P, N)
    cfg: ArchConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    bsz = xin.shape[0]
    di, h, n, p = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", xin, params["in_proj"])[:, 0]
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    # conv over the (K-1) stored inputs + current
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = window[:, 1:, :]
    x, b_mat, c_mat = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a[None, :])                                  # (B,H)
    xh = x.reshape(bsz, h, p).astype(jnp.float32)
    upd = jnp.einsum(
        "bhp,bn,bh->bhpn", xh, b_mat.astype(jnp.float32), dt
    )
    ssm_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, c_mat.astype(jnp.float32))
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(bsz, di).astype(xin.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(
        xin.dtype
    ) * params["norm"]
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None, :]
    return out, new_conv_state, ssm_state


def ssd_reference(
    x: jax.Array, dt: jax.Array, a: jax.Array, b_mat: jax.Array, c_mat: jax.Array
) -> jax.Array:
    """O(S²) oracle: y_t = Σ_{s≤t} C_t·(∏_{r=s+1..t} exp(dt_r a)) B_s x_s dt_s."""
    bsz, s, h, p = x.shape
    da = (dt * a[None, None, :]).astype(jnp.float32)      # (B,S,H)
    lmat = jnp.exp(_segsum(da.transpose(0, 2, 1)))        # (B,H,S,S)
    scores = jnp.einsum("bqn,bkn->bqk", c_mat, b_mat)     # (B,S,S)
    xdt = x.astype(jnp.float32) * dt[..., None]
    return jnp.einsum("bhqk,bqk,bkhp->bqhp", lmat, scores, xdt)
