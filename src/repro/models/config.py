"""Architecture configuration.

One ``ArchConfig`` describes any of the 10 assigned architectures (plus
reduced smoke-test variants).  The layer stack is expressed as a repeating
*pattern* of block kinds — scanning over pattern units keeps HLO size
O(pattern) instead of O(layers) while preserving layer order, and gives the
roofline tool natural per-block cost units.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# block kinds usable in a layer pattern
ATTN = "attn"            # global self-attention + MLP
LOCAL = "local"          # sliding-window self-attention + MLP
MAMBA2 = "mamba2"        # Mamba-2 / SSD block
SLSTM = "slstm"          # xLSTM scalar-memory block
MLSTM = "mlstm"          # xLSTM matrix-memory block
MOE = "moe"              # attention + MoE FFN
DENSE = "dense"          # attention + dense FFN (used inside MoE archs)
SHARED_ATTN = "shared_attn"  # zamba2 shared-weight attention block
CROSS = "cross"          # self-attention + cross-attention + MLP (vlm/encdec)

KNOWN_BLOCKS = {ATTN, LOCAL, MAMBA2, SLSTM, MLSTM, MOE, DENSE, SHARED_ATTN, CROSS}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- layer stack: `pattern` repeated `pattern_repeats` times, then
    # `tail` (non-repeated) blocks.  len(pattern)*repeats + len(tail) = L.
    pattern: Tuple[str, ...] = (ATTN,)
    pattern_repeats: int = 1
    tail: Tuple[str, ...] = ()
    head_dim: Optional[int] = None    # default d_model // num_heads
    qkv_bias: bool = False
    # --- gemma2-style extras
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    sliding_window: int = 0           # for LOCAL blocks
    post_block_norm: bool = False     # gemma2 sandwich norms
    # --- MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert hidden width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance aux loss
    # expert-parallel dispatch groups (perf knob): 0/1 = global top-C
    # dispatch (GShard-style, baseline); g>1 = per-group routing with
    # per-group capacity — groups align with the data axis so token
    # gather/scatter stays shard-local and only the dispatched copies move
    # (EP all-to-all).  See EXPERIMENTS.md §Perf (kimi-k2 iterations).
    moe_dispatch_groups: int = 0
    # --- SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256              # SSD chunk length
    # --- xLSTM
    xlstm_head_dim: int = 0           # default d_model // num_heads
    # --- encoder-decoder (whisper-style)
    encoder_layers: int = 0
    encoder_seq: int = 0              # stub frontend tokens (audio frames)
    # --- VLM cross-attention
    vision_seq: int = 0               # stub patch-embedding tokens
    # --- misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"
    dtype: str = "bfloat16"
    # attention reference path: query-chunk size for memory-efficient attn
    attn_q_chunk: int = 1024
    # decode KV-cache dtype: "bfloat16" (baseline) or "int8" (per-token,
    # per-head absmax quantization — halves decode HBM traffic; §Perf)
    kv_cache_dtype: str = "bfloat16"
    # remat policy for train: "none" | "block" | "dots"
    remat: str = "block"
    use_pallas: bool = False          # TPU kernels (XLA ref path when False)

    # ------------------------------------------------------------------ api
    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.pattern_repeats + len(self.tail)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True iff per-token decode state is o(seq): SSM/hybrid/linear-attn.

        Hybrid archs still carry attention KV caches, but those shard over
        the mesh; pure full-attention archs are skipped for ``long_500k``
        (see DESIGN.md §Arch-applicability)."""
        kinds = set(self.pattern) | set(self.tail)
        return bool(kinds & {MAMBA2, SLSTM, MLSTM})

    def validate(self) -> "ArchConfig":
        kinds = set(self.pattern) | set(self.tail)
        unknown = kinds - KNOWN_BLOCKS
        if unknown:
            raise ValueError(f"{self.name}: unknown block kinds {unknown}")
        if self.num_heads % max(1, self.num_kv_heads):
            raise ValueError(f"{self.name}: heads not divisible by kv heads")
        if MOE in kinds and not (self.num_experts and self.experts_per_token):
            raise ValueError(f"{self.name}: MoE blocks need expert config")
        if MAMBA2 in kinds and not self.ssm_state:
            raise ValueError(f"{self.name}: mamba2 blocks need ssm_state")
        return self

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test-sized sibling of this architecture (same family,
        same block pattern, tiny dims)."""
        defaults = dict(
            name=f"{self.name}-smoke",
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            pattern=self.pattern,
            pattern_repeats=min(self.pattern_repeats, 2),
            tail=self.tail[: 2],
            head_dim=16 if self.head_dim else None,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token
            else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=32 if self.moe_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=24 if self.encoder_seq else 0,
            vision_seq=24 if self.vision_seq else 0,
            sliding_window=16 if self.sliding_window else 0,
            attn_q_chunk=32,
            dtype="float32",
            remat="none",
        )
        defaults.update(overrides)
        keep = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name not in defaults
        }
        return ArchConfig(**{**keep, **defaults}).validate()


# global registry populated by repro.configs
_REGISTRY: Dict[str, ArchConfig] = {}


def register(config: ArchConfig) -> ArchConfig:
    config.validate()
    _REGISTRY[config.name] = config
    return config


def get_config(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (populates the registry)

    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}"
        ) from None


def list_configs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401

    return tuple(sorted(_REGISTRY))
