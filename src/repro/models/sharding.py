"""Logical-axis sharding (MaxText-style logical→physical rules).

Every parameter and activation is annotated with *logical* axis names; a rule
table maps those to mesh axes.  The same model code then runs on the 1-device
CPU mesh (everything maps to None), the single-pod ``(data, model)`` mesh, and
the multi-pod ``(pod, data, model)`` mesh — only the rules change.

Baseline layout (megatron TP + DP, the dry-run default):

=============  =========================== =============
logical axis    meaning                     physical
=============  =========================== =============
``batch``       global batch                ("pod","data")
``seq``         sequence (activations)      None (SP: "model")
``cache_seq``   KV-cache sequence           None (long-ctx: "data")
``vocab``       embedding/logits vocab      "model"
``heads``       attention heads             "model"
``kv_heads``    KV heads                    "model"
``mlp``         FFN hidden                  "model"
``experts``     MoE experts                 "model"
``embed``       d_model                     None
``ssm_heads``   SSD heads                   "model"
=============  =========================== =============
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]


class Ax:
    """Leaf marker carrying logical axis names for roofline body lowering.

    A plain (unregistered) class, so jax.tree treats it as a leaf — axes
    trees mirror value pytrees exactly.
    """

    __slots__ = ("axes",)

    def __init__(self, axes: Sequence[Optional[str]]):
        self.axes = tuple(axes)

    def __repr__(self) -> str:
        return f"Ax{self.axes}"


def ax(*names: Optional[str]) -> Ax:
    return Ax(names)


AX0 = Ax(())  # scalar / replicated

# default: megatron-style tensor parallel over "model", batch over pod+data
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "q_per_kv": None,
    "head_dim": None,
    "embed": None,
    "mlp": "model",
    "experts": "model",
    "expert_embed": "data",   # expert tensors' d_model axis: 2-D (model×data)
    "expert_mlp": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,       # stacked scanned params
    "frames": None,       # stub modality tokens
}

_state = threading.local()


def current_rules() -> Rules:
    return getattr(_state, "rules", DEFAULT_RULES)


def current_mesh() -> Optional[Mesh]:
    mesh = getattr(_state, "mesh", None)
    if mesh is not None:
        return mesh
    env = jax.sharding.get_abstract_mesh()
    return None if env is None or env.empty else None


@contextmanager
def sharding_rules(rules: Rules, mesh: Optional[Mesh] = None):
    prev_rules = getattr(_state, "rules", None)
    prev_mesh = getattr(_state, "mesh", None)
    _state.rules = {**DEFAULT_RULES, **rules}
    _state.mesh = mesh
    try:
        yield
    finally:
        if prev_rules is None:
            del _state.rules
        else:
            _state.rules = prev_rules
        _state.mesh = prev_mesh


def logical_to_spec(axes: Sequence[Optional[str]], rules: Optional[Rules] = None) -> P:
    """Map logical axis names to a PartitionSpec under the current rules,
    dropping mesh axes that do not exist in the active mesh."""
    rules = rules or current_rules()
    mesh = getattr(_state, "mesh", None)
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    out = []
    used = set()
    for ax in axes:
        entry = rules.get(ax) if ax is not None else None
        if entry is None:
            out.append(None)
            continue
        parts = entry if isinstance(entry, tuple) else (entry,)
        parts = tuple(
            p for p in parts
            if (mesh_axes is None or p in mesh_axes) and p not in used
        )
        used.update(parts)
        if not parts:
            out.append(None)
        elif len(parts) == 1:
            out.append(parts[0])
        else:
            out.append(parts)
    return P(*out)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op off-mesh)."""
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return x
    spec = logical_to_spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*axes: Optional[str]) -> Optional[NamedSharding]:
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(axes))
