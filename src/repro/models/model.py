"""Model assembly: any ``ArchConfig`` → parameter specs + three lowerable
entry points (train forward/loss, prefill, decode step).

The layer stack is ``pattern × pattern_repeats`` followed by ``tail``.  The
repeated pattern is executed with one ``instrumented_scan`` over stacked
parameters (HLO size O(|pattern|), roofline-correctable trip counts); tail
blocks are unrolled.  Every block kind provides three modes:

  * ``seq``      — full-sequence forward (training),
  * ``prefill``  — full-sequence forward that also emits the decode state,
  * ``decode``   — one-token step over the decode state.

Scan bodies take all tensors through carry/xs (no tracer closures — required
by the roofline tool, see ``models/scan.py``): shared zamba2 weights, encoder
context, the MoE aux-loss accumulator and the decode position ride the carry.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ssm, xlstm
from .config import (
    ATTN, CROSS, DENSE, LOCAL, MAMBA2, MLSTM, MOE, SHARED_ATTN, SLSTM,
    ArchConfig,
)
from .layers import (
    attention_defs, decode_attention, mlp, mlp_defs, multi_head_attention,
    prefill_chunk_attention, prefill_kv, rmsnorm, rmsnorm_def,
)
from .moe import moe_defs, moe_ffn
from .params import ParamDef, abstract, axes_tree, initialize, is_def, specs
from .scan import instrumented_scan
from .sharding import AX0, Ax, constrain

PyTree = Any


def padded_vocab(cfg: ArchConfig) -> int:
    """Vocab padded to a multiple of 256 so embedding/logit tables shard over
    any model-axis size ≤ 256 (Megatron-style vocab padding)."""
    return -(-cfg.vocab_size // 256) * 256


# ---------------------------------------------------------------------------
# per-block parameter definitions
# ---------------------------------------------------------------------------

def _block_defs(kind: str, cfg: ArchConfig) -> Dict[str, PyTree]:
    d, dt = cfg.d_model, cfg.dtype
    ln = lambda: rmsnorm_def(d, dt)  # noqa: E731
    if kind in (ATTN, LOCAL, DENSE):
        defs = {"ln1": ln(), "attn": attention_defs(cfg), "ln2": ln(),
                "mlp": mlp_defs(cfg)}
        if cfg.post_block_norm:
            defs["post1"] = ln()
            defs["post2"] = ln()
        return defs
    if kind == MOE:
        return {"ln1": ln(), "attn": attention_defs(cfg), "ln2": ln(),
                "moe": moe_defs(cfg)}
    if kind == MAMBA2:
        return {"ln1": ln(), "mamba": ssm.mamba2_defs(cfg)}
    if kind == SLSTM:
        return {"ln1": ln(), "slstm": xlstm.slstm_defs(cfg)}
    if kind == MLSTM:
        return {"ln1": ln(), "mlstm": xlstm.mlstm_defs(cfg)}
    if kind == SHARED_ATTN:
        # weights live in the shared tree; per-application norms only
        return {"ln1": ln(), "ln2": ln()}
    if kind == CROSS:
        return {"ln1": ln(), "attn": attention_defs(cfg), "lnx": ln(),
                "xattn": attention_defs(cfg, cross=True), "ln2": ln(),
                "mlp": mlp_defs(cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def _stack_defs(tree: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda p: ParamDef((n,) + p.shape, ("layers",) + p.axes, p.dtype,
                           p.init, p.scale),
        tree,
        is_leaf=is_def,
    )


def _pattern_names(pattern) -> Tuple[str, ...]:
    return tuple(f"{i:02d}_{kind}" for i, kind in enumerate(pattern))


# ---------------------------------------------------------------------------
# decode-state definitions (zeros)
# ---------------------------------------------------------------------------

def _block_state_defs(kind: str, cfg: ArchConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    if kind in (ATTN, LOCAL, DENSE, MOE, SHARED_ATTN):
        kdt = cfg.kv_cache_dtype
        out = {
            "k": ParamDef((batch, max_len, kv, hd),
                          ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                          kdt, init="zeros"),
            "v": ParamDef((batch, max_len, kv, hd),
                          ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                          kdt, init="zeros"),
        }
        if kdt == "int8":
            out["ks"] = ParamDef((batch, max_len, kv),
                                 ("cache_batch", "cache_seq", "kv_heads"),
                                 "float32", init="zeros")
            out["vs"] = ParamDef((batch, max_len, kv),
                                 ("cache_batch", "cache_seq", "kv_heads"),
                                 "float32", init="zeros")
        return out
    if kind == CROSS:
        enc = cfg.encoder_seq or cfg.vision_seq
        return {
            "k": ParamDef((batch, max_len, kv, hd),
                          ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                          cfg.dtype, init="zeros"),
            "v": ParamDef((batch, max_len, kv, hd),
                          ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                          cfg.dtype, init="zeros"),
            "ck": ParamDef((batch, enc, kv, hd),
                           ("cache_batch", "frames", "kv_heads", "head_dim"),
                           cfg.dtype, init="zeros"),
            "cv": ParamDef((batch, enc, kv, hd),
                           ("cache_batch", "frames", "kv_heads", "head_dim"),
                           cfg.dtype, init="zeros"),
        }
    if kind == MAMBA2:
        di, n, h, p = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_head_dim)
        return {
            "conv": ParamDef((batch, cfg.ssm_conv - 1, di + 2 * n),
                             ("cache_batch", None, "mlp"), cfg.dtype,
                             init="zeros"),
            "ssm": ParamDef((batch, h, p, n),
                            ("cache_batch", "ssm_heads", None, None),
                            "float32", init="zeros"),
        }
    if kind == MLSTM:
        di = 2 * cfg.d_model
        h = cfg.num_heads
        p = di // h
        return {
            "c": ParamDef((batch, h, p, p), ("cache_batch", "heads", None, None),
                          "float32", init="zeros"),
            "n": ParamDef((batch, h, p), ("cache_batch", "heads", None),
                          "float32", init="zeros"),
            "m": ParamDef((batch, h), ("cache_batch", "heads"),
                          "float32", init="neg_inf"),
        }
    if kind == SLSTM:
        h = cfg.num_heads
        p = cfg.d_model // h
        leaf = lambda init: ParamDef(  # noqa: E731
            (batch, h, p), ("cache_batch", "heads", None), "float32", init=init)
        return {"c": leaf("zeros"), "n": leaf("zeros"), "h": leaf("zeros"),
                "m": leaf("neg_inf")}
    raise ValueError(kind)


def init_state_leaf(d: ParamDef) -> jax.Array:
    if d.init == "neg_inf":
        return jnp.full(d.shape, -jnp.inf, jnp.dtype(d.dtype))
    return jnp.zeros(d.shape, jnp.dtype(d.dtype))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

@dataclass
class Ctx:
    """Non-parameter context threaded through scan carries."""
    shared: Optional[Dict] = None      # zamba2 shared attn+mlp weights
    enc: Optional[jax.Array] = None    # encoder / vision context (B, T, d)
    position: Optional[jax.Array] = None  # decode position (scalar int32)


def _attn_mlp_seq(bp, x, cfg, *, window=0, moe_block=False, ctx: Ctx,
                  shared=False):
    eps = cfg.norm_eps
    ap = ctx.shared["attn"] if shared else bp["attn"]
    h = multi_head_attention(ap, rmsnorm(x, bp["ln1"], eps), cfg,
                             causal=True, window=window)
    if cfg.post_block_norm:
        h = rmsnorm(h, bp["post1"], eps)
    x = x + h
    aux = jnp.float32(0)
    if moe_block:
        h, aux = moe_ffn(bp["moe"], rmsnorm(x, bp["ln2"], eps), cfg)
    else:
        mp = ctx.shared["mlp"] if shared else bp["mlp"]
        h = mlp(mp, rmsnorm(x, bp["ln2"], eps), cfg.act)
    if cfg.post_block_norm:
        h = rmsnorm(h, bp["post2"], eps)
    return x + h, aux


def block_seq(kind: str, bp, x, cfg: ArchConfig, ctx: Ctx):
    """Full-sequence block application.  Returns (x, aux_loss)."""
    eps = cfg.norm_eps
    if kind in (ATTN, DENSE):
        return _attn_mlp_seq(bp, x, cfg, ctx=ctx)
    if kind == LOCAL:
        return _attn_mlp_seq(bp, x, cfg, window=cfg.sliding_window, ctx=ctx)
    if kind == MOE:
        return _attn_mlp_seq(bp, x, cfg, moe_block=True, ctx=ctx)
    if kind == SHARED_ATTN:
        return _attn_mlp_seq(bp, x, cfg, ctx=ctx, shared=True)
    if kind == MAMBA2:
        return x + ssm.mamba2_forward(bp["mamba"], rmsnorm(x, bp["ln1"], eps),
                                      cfg), jnp.float32(0)
    if kind == SLSTM:
        return x + xlstm.slstm_forward(bp["slstm"], rmsnorm(x, bp["ln1"], eps),
                                       cfg), jnp.float32(0)
    if kind == MLSTM:
        return x + xlstm.mlstm_forward(bp["mlstm"], rmsnorm(x, bp["ln1"], eps),
                                       cfg), jnp.float32(0)
    if kind == CROSS:
        x = x + multi_head_attention(bp["attn"], rmsnorm(x, bp["ln1"], eps),
                                     cfg, causal=True)
        x = x + multi_head_attention(bp["xattn"], rmsnorm(x, bp["lnx"], eps),
                                     cfg, causal=False, xkv=ctx.enc)
        return x + mlp(bp["mlp"], rmsnorm(x, bp["ln2"], eps), cfg.act), \
            jnp.float32(0)
    raise ValueError(kind)


def block_prefill(kind: str, bp, x, cfg: ArchConfig, ctx: Ctx, max_len: int):
    """Sequence forward + decode-state construction.  Returns (x, state, aux)."""
    eps = cfg.norm_eps
    if kind in (ATTN, LOCAL, DENSE, MOE, SHARED_ATTN):
        ap = ctx.shared["attn"] if kind == SHARED_ATTN else bp["attn"]
        xin = rmsnorm(x, bp["ln1"], eps)
        k, v = prefill_kv(ap, xin, cfg, max_len)
        y, aux = block_seq(kind, bp, x, cfg, ctx)
        if cfg.kv_cache_dtype == "int8":
            from .layers import kv_quantize

            k8, ks = kv_quantize(k)
            v8, vs = kv_quantize(v)
            return y, {"k": k8, "v": v8, "ks": ks, "vs": vs}, aux
        return y, {"k": k, "v": v}, aux
    if kind == CROSS:
        xin = rmsnorm(x, bp["ln1"], eps)
        k, v = prefill_kv(bp["attn"], xin, cfg, max_len)
        enc = ctx.enc
        ck = jnp.einsum("bsd,dhk->bshk", enc, bp["xattn"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc, bp["xattn"]["wv"])
        y, aux = block_seq(kind, bp, x, cfg, ctx)
        return y, {"k": k, "v": v, "ck": ck.astype(k.dtype),
                   "cv": cv.astype(v.dtype)}, aux
    if kind == MAMBA2:
        xin = rmsnorm(x, bp["ln1"], eps)
        y, state = ssm.mamba2_sequence(bp["mamba"], xin, cfg, init_state=None)
        # conv tail: the last K−1 post-activation conv inputs
        di, n = cfg.ssm_d_inner, cfg.ssm_state
        proj = jnp.einsum("bsd,de->bse", xin, bp["mamba"]["in_proj"])
        xbc = proj[..., di:2 * di + 2 * n]
        km1 = cfg.ssm_conv - 1
        conv = xbc[:, -km1:, :]
        pad = km1 - conv.shape[1]
        if pad > 0:
            conv = jnp.pad(conv, ((0, 0), (pad, 0), (0, 0)))
        return x + y, {"conv": conv.astype(jnp.dtype(cfg.dtype)),
                       "ssm": state}, jnp.float32(0)
    if kind == MLSTM:
        xin = rmsnorm(x, bp["ln1"], eps)
        y, (c, nn, m) = xlstm.mlstm_sequence(bp["mlstm"], xin, cfg, state=None)
        return x + y, {"c": c, "n": nn, "m": m}, jnp.float32(0)
    if kind == SLSTM:
        xin = rmsnorm(x, bp["ln1"], eps)
        y, (c, nn, hh, m) = xlstm.slstm_sequence(bp["slstm"], xin, cfg,
                                                 state=None)
        return x + y, {"c": c, "n": nn, "h": hh, "m": m}, jnp.float32(0)
    raise ValueError(kind)


def block_decode(kind: str, bp, x, st, cfg: ArchConfig, ctx: Ctx):
    """One-token step.  x: (B,1,d).  Returns (x, new_state)."""
    eps = cfg.norm_eps
    pos = ctx.position
    if kind in (ATTN, LOCAL, DENSE, MOE, SHARED_ATTN):
        ap = ctx.shared["attn"] if kind == SHARED_ATTN else bp["attn"]
        window = cfg.sliding_window if kind == LOCAL else 0
        h, ck, cv, ks, vs = decode_attention(
            ap, rmsnorm(x, bp["ln1"], eps), st["k"], st["v"], pos, cfg,
            window=window, k_scale=st.get("ks"), v_scale=st.get("vs"))
        if cfg.post_block_norm:
            h = rmsnorm(h, bp["post1"], eps)
        x = x + h
        if kind == MOE:
            h, _ = moe_ffn(bp["moe"], rmsnorm(x, bp["ln2"], eps), cfg)
        else:
            mp = ctx.shared["mlp"] if kind == SHARED_ATTN else bp["mlp"]
            h = mlp(mp, rmsnorm(x, bp["ln2"], eps), cfg.act)
        if cfg.post_block_norm:
            h = rmsnorm(h, bp["post2"], eps)
        new_st = {**st, "k": ck, "v": cv}
        if ks is not None:
            new_st["ks"], new_st["vs"] = ks, vs
        return x + h, new_st
    if kind == CROSS:
        h, ck, cv, ks, vs = decode_attention(
            bp["attn"], rmsnorm(x, bp["ln1"], eps), st["k"], st["v"], pos,
            cfg, k_scale=st.get("ks"), v_scale=st.get("vs"))
        x = x + h
        h, _, _, _, _ = decode_attention(
            bp["xattn"], rmsnorm(x, bp["lnx"], eps), st["ck"], st["cv"],
            pos, cfg, cross=True)
        x = x + h
        x = x + mlp(bp["mlp"], rmsnorm(x, bp["ln2"], eps), cfg.act)
        new_st = {**st, "k": ck, "v": cv}
        if ks is not None:
            new_st["ks"], new_st["vs"] = ks, vs
        return x, new_st
    if kind == MAMBA2:
        y, conv, ssm_st = ssm.mamba2_decode_step(
            bp["mamba"], rmsnorm(x, bp["ln1"], eps), st["conv"], st["ssm"], cfg)
        return x + y, {"conv": conv, "ssm": ssm_st}
    if kind == MLSTM:
        y, (c, nn, m) = xlstm.mlstm_decode_step(
            bp["mlstm"], rmsnorm(x, bp["ln1"], eps), (st["c"], st["n"], st["m"]),
            cfg)
        return x + y, {"c": c, "n": nn, "m": m}
    if kind == SLSTM:
        y, (c, nn, hh, m) = xlstm.slstm_decode_step(
            bp["slstm"], rmsnorm(x, bp["ln1"], eps),
            (st["c"], st["n"], st["h"], st["m"]), cfg)
        return x + y, {"c": c, "n": nn, "h": hh, "m": m}
    raise ValueError(kind)


# block kinds whose decode state can be built incrementally, chunk by chunk,
# into a pre-allocated cache.  Recurrent kinds (mamba2/xlstm) carry conv/
# hidden tails that this path does not stitch across chunk boundaries.
CHUNKABLE_KINDS = (ATTN, LOCAL, DENSE, MOE, SHARED_ATTN)


def block_prefill_chunk(kind: str, bp, x, st, cfg: ArchConfig, ctx: Ctx):
    """Chunked prefill over an existing decode state.  x: (B, C, d);
    ``ctx.position`` is the chunk's global offset (scalar int32).  Returns
    (x, new_state).  Attention-family kinds only — see CHUNKABLE_KINDS."""
    eps = cfg.norm_eps
    if kind not in CHUNKABLE_KINDS:
        raise NotImplementedError(
            f"chunked prefill is not supported for block kind {kind!r}")
    ap = ctx.shared["attn"] if kind == SHARED_ATTN else bp["attn"]
    window = cfg.sliding_window if kind == LOCAL else 0
    h, ck, cv, ks, vs = prefill_chunk_attention(
        ap, rmsnorm(x, bp["ln1"], eps), st["k"], st["v"], ctx.position, cfg,
        window=window, k_scale=st.get("ks"), v_scale=st.get("vs"))
    if cfg.post_block_norm:
        h = rmsnorm(h, bp["post1"], eps)
    x = x + h
    if kind == MOE:
        h, _ = moe_ffn(bp["moe"], rmsnorm(x, bp["ln2"], eps), cfg)
    else:
        mp = ctx.shared["mlp"] if kind == SHARED_ATTN else bp["mlp"]
        h = mlp(mp, rmsnorm(x, bp["ln2"], eps), cfg.act)
    if cfg.post_block_norm:
        h = rmsnorm(h, bp["post2"], eps)
    new_st = {**st, "k": ck, "v": cv}
    if ks is not None:
        new_st["ks"], new_st["vs"] = ks, vs
    return x + h, new_st


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class Model:
    """Stateless model functions for one architecture."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg.validate()
        self.pattern_names = _pattern_names(cfg.pattern)
        self.tail_names = tuple(
            f"t{i:02d}_{kind}" for i, kind in enumerate(cfg.tail))
        self.has_shared = SHARED_ATTN in set(cfg.pattern) | set(cfg.tail)
        self.has_moe = MOE in set(cfg.pattern) | set(cfg.tail)

    # --------------------------------------------- roofline logical axes
    def _unit_axes(self):
        cfg = self.cfg
        return {name: axes_tree(_block_defs(kind, cfg))
                for name, kind in zip(self.pattern_names, cfg.pattern)}

    def _unit_state_axes(self):
        cfg = self.cfg
        return {name: axes_tree(_block_state_defs(kind, cfg, 1, 1))
                for name, kind in zip(self.pattern_names, cfg.pattern)}

    def _shared_axes(self):
        if not self.has_shared:
            return AX0
        return axes_tree({"attn": attention_defs(self.cfg),
                          "mlp": mlp_defs(self.cfg)})

    def _enc_axes(self, have_enc: bool):
        return Ax(("batch", None, "embed")) if have_enc else AX0

    # ------------------------------------------------------------ parameters
    def param_defs(self) -> Dict[str, PyTree]:
        cfg = self.cfg
        vp = padded_vocab(cfg)
        defs: Dict[str, PyTree] = {
            "embed": ParamDef((vp, cfg.d_model), ("vocab", "embed"),
                              cfg.dtype, init="embed",
                              scale=cfg.d_model ** -0.5),
            "final_norm": rmsnorm_def(cfg.d_model, cfg.dtype),
        }
        unit = {name: _block_defs(kind, cfg)
                for name, kind in zip(self.pattern_names, cfg.pattern)}
        defs["pattern"] = _stack_defs(unit, cfg.pattern_repeats)
        if cfg.tail:
            defs["tail"] = {name: _block_defs(kind, cfg)
                            for name, kind in zip(self.tail_names, cfg.tail)}
        if self.has_shared:
            defs["shared"] = {"attn": attention_defs(cfg),
                              "mlp": mlp_defs(cfg)}
        if not cfg.tie_embeddings:
            defs["lm_head"] = ParamDef((cfg.d_model, vp), ("embed", "vocab"),
                                       cfg.dtype)
        if cfg.is_encoder_decoder:
            enc_unit = {f"e00_{ATTN}": _block_defs(ATTN, cfg)}
            defs["encoder"] = {
                "pattern": _stack_defs(enc_unit, cfg.encoder_layers),
                "norm": rmsnorm_def(cfg.d_model, cfg.dtype),
            }
        return defs

    def abstract_params(self):
        return abstract(self.param_defs())

    def param_specs(self):
        return specs(self.param_defs())

    def init_params(self, key: jax.Array):
        return initialize(key, self.param_defs())

    # ---------------------------------------------------------------- embed
    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        return constrain(x, "batch", "seq", "embed")

    def _logits(self, params, x):
        cfg = self.cfg
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        logits = logits.astype(jnp.float32)
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        # mask vocab padding
        vp = logits.shape[-1]
        if vp != cfg.vocab_size:
            mask = jnp.arange(vp) < cfg.vocab_size
            logits = jnp.where(mask, logits, -1e30)
        return constrain(logits, "batch", "seq", "vocab")

    # -------------------------------------------------------------- encoder
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """Bidirectional encoder over stub frame embeddings (B, T, d)."""
        cfg = self.cfg
        enc = params["encoder"]
        name = f"e00_{ATTN}"

        def body(carry, bp_slice):
            x, aux = carry
            bp = bp_slice[name]
            h = multi_head_attention(bp["attn"],
                                     rmsnorm(x, bp["ln1"], cfg.norm_eps),
                                     cfg, causal=False)
            x = x + h
            x = x + mlp(bp["mlp"], rmsnorm(x, bp["ln2"], cfg.norm_eps),
                        cfg.act)
            return (x, aux), None

        # NB: encoder frames keep seq unsharded — frame counts (1500) are
        # not divisible by the model axis, unlike decoder token counts.
        (x, _), _ = instrumented_scan(
            body, (frames, jnp.float32(0)), enc["pattern"],
            name="encoder_layers",
            logical_axes=((Ax(("batch", None, "embed")), AX0),
                          axes_tree({name: _block_defs(ATTN, cfg)})))
        return rmsnorm(x, enc["norm"], cfg.norm_eps)

    def _context(self, params, frontend: Optional[jax.Array]) -> Ctx:
        cfg = self.cfg
        enc = None
        if cfg.is_encoder_decoder:
            assert frontend is not None, "encoder-decoder arch needs frames"
            enc = self.encode(params, frontend)
        elif cfg.vision_seq:
            assert frontend is not None, "vlm arch needs patch embeddings"
            enc = frontend
        shared = params.get("shared") if self.has_shared else None
        return Ctx(shared=shared, enc=enc)

    # -------------------------------------------------------------- forward
    def forward(self, params, tokens, frontend=None):
        """Training / scoring forward.  Returns (logits, aux_loss)."""
        cfg = self.cfg
        ctx = self._context(params, frontend)
        x = self._embed(params, tokens)
        kinds = dict(zip(self.pattern_names, cfg.pattern))

        def unit(x, bp_slice, shared, enc, aux):
            c = Ctx(shared=shared, enc=enc)
            for name in self.pattern_names:
                x, a = block_seq(kinds[name], bp_slice[name], x, cfg, c)
                aux = aux + a
            return x, aux

        if cfg.remat == "block":
            unit = jax.checkpoint(unit)
        elif cfg.remat == "dots":
            # save matmul outputs, recompute only cheap elementwise ops in
            # the backward pass — trades HBM for a ~25% FLOP reduction
            unit = jax.checkpoint(
                unit,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        def body(carry, bp_slice):
            x, shared, enc, aux = carry
            x, aux = unit(x, bp_slice, shared, enc, aux)
            return (x, shared, enc, aux), None

        shared0 = ctx.shared if ctx.shared is not None else jnp.float32(0)
        enc0 = ctx.enc if ctx.enc is not None else jnp.float32(0)
        (x, _, _, aux), _ = instrumented_scan(
            body, (x, shared0, enc0, jnp.float32(0)), params["pattern"],
            name="pattern_layers",
            logical_axes=((Ax(("batch", "seq", "embed")), self._shared_axes(),
                           self._enc_axes(ctx.enc is not None), AX0),
                          self._unit_axes()))
        for name, kind in zip(self.tail_names, cfg.tail):
            x, a = block_seq(kind, params["tail"][name], x, cfg, ctx)
            aux = aux + a
        return self._logits(params, x), aux

    # ----------------------------------------------------------------- loss
    def loss_fn(self, params, batch):
        """Next-token cross entropy.  batch: {tokens, labels[, frontend]}."""
        cfg = self.cfg
        logits, aux = self.forward(params, batch["tokens"],
                                   batch.get("frontend"))
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = jnp.sum((logz - gold) * mask) / denom
        total = ce + cfg.router_aux_weight * aux
        return total, {"ce": ce, "aux": aux,
                       "ppl_log": ce}

    # ---------------------------------------------------------- decode state
    def decode_state_defs(self, batch: int, max_len: int) -> Dict[str, PyTree]:
        cfg = self.cfg
        unit = {name: _block_state_defs(kind, cfg, batch, max_len)
                for name, kind in zip(self.pattern_names, cfg.pattern)}
        out = {"pattern": _stack_defs(unit, cfg.pattern_repeats)}
        if cfg.tail:
            out["tail"] = {
                name: _block_state_defs(kind, cfg, batch, max_len)
                for name, kind in zip(self.tail_names, cfg.tail)}
        return out

    def init_decode_state(self, batch: int, max_len: int):
        return jax.tree.map(init_state_leaf, self.decode_state_defs(batch, max_len),
                            is_leaf=is_def)

    # -------------------------------------------------------------- prefill
    def prefill(self, params, tokens, max_len: int, frontend=None):
        """Process the whole prompt; returns (last-position logits, state)."""
        cfg = self.cfg
        ctx = self._context(params, frontend)
        x = self._embed(params, tokens)
        kinds = dict(zip(self.pattern_names, cfg.pattern))

        def body(carry, bp_slice):
            x, shared, enc, aux = carry
            c = Ctx(shared=None if isinstance(shared, jax.Array) else shared,
                    enc=None if (isinstance(enc, jax.Array) and enc.ndim == 0)
                    else enc)
            states = {}
            for name in self.pattern_names:
                x, st, a = block_prefill(kinds[name], bp_slice[name], x, cfg,
                                         c, max_len)
                states[name] = st
                aux = aux + a
            return (x, shared, enc, aux), states

        shared0 = ctx.shared if ctx.shared is not None else jnp.float32(0)
        enc0 = ctx.enc if ctx.enc is not None else jnp.float32(0)
        (x, _, _, aux), states = instrumented_scan(
            body, (x, shared0, enc0, jnp.float32(0)), params["pattern"],
            name="prefill_layers",
            logical_axes=((Ax(("batch", "seq", "embed")), self._shared_axes(),
                           self._enc_axes(ctx.enc is not None), AX0),
                          self._unit_axes()))
        out = {"pattern": states}
        if cfg.tail:
            tail_states = {}
            for name, kind in zip(self.tail_names, cfg.tail):
                x, st, _ = block_prefill(kind, params["tail"][name], x, cfg,
                                         ctx, max_len)
                tail_states[name] = st
            out["tail"] = tail_states
        logits = self._logits(params, x[:, -1:, :])
        return logits, out

    # ------------------------------------------------------ chunked prefill
    @property
    def supports_chunked_prefill(self) -> bool:
        """True iff every block kind can prefill incrementally into a
        pre-allocated decode state (continuous batching needs this)."""
        kinds = set(self.cfg.pattern) | set(self.cfg.tail)
        return kinds <= set(CHUNKABLE_KINDS)

    def prefill_chunk(self, params, state, tokens, offset):
        """Incremental prefill for continuous batching: run ``tokens``
        (B, C) int32 at global positions ``[offset, offset+C)``, writing
        K/V into the given decode state.  Shapes are fixed by (B, C), so
        one jitted call serves prompts of any length; the chunk write must
        stay within the state's ``max_len``.  Returns (logits (B, C, V),
        new_state)."""
        cfg = self.cfg
        if not self.supports_chunked_prefill:
            bad = sorted((set(cfg.pattern) | set(cfg.tail))
                         - set(CHUNKABLE_KINDS))
            raise NotImplementedError(
                f"chunked prefill unsupported for block kinds {bad}")
        shared = params.get("shared") if self.has_shared else None
        x = self._embed(params, tokens)
        kinds = dict(zip(self.pattern_names, cfg.pattern))

        def body(carry, xs):
            x, shared, off = carry
            bp_slice, st_slice = xs
            c = Ctx(shared=None if isinstance(shared, jax.Array) else shared,
                    position=off)
            new_states = {}
            for name in self.pattern_names:
                x, st = block_prefill_chunk(kinds[name], bp_slice[name], x,
                                            st_slice[name], cfg, c)
                new_states[name] = st
            return (x, shared, off), new_states

        shared0 = shared if shared is not None else jnp.float32(0)
        (x, _, _), new_pattern = instrumented_scan(
            body, (x, shared0, jnp.asarray(offset, jnp.int32)),
            (params["pattern"], state["pattern"]), name="prefill_chunk_layers",
            logical_axes=((Ax(("batch", "seq", "embed")), self._shared_axes(),
                           AX0),
                          (self._unit_axes(), self._unit_state_axes())))
        out = {"pattern": new_pattern}
        if cfg.tail:
            ctx = Ctx(shared=shared, position=jnp.asarray(offset, jnp.int32))
            tail_states = {}
            for name, kind in zip(self.tail_names, cfg.tail):
                x, st = block_prefill_chunk(kind, params["tail"][name], x,
                                            state["tail"][name], cfg, ctx)
                tail_states[name] = st
            out["tail"] = tail_states
        return self._logits(params, x), out

    # --------------------------------------------------------------- decode
    def decode_step(self, params, state, tokens, position, frontend=None):
        """One decode step.  tokens: (B, 1) int32; position: scalar int32,
        or (B,) int32 for continuous batching (each row at its own offset;
        a row position of ``max_len`` is a write-proof free-slot sentinel).
        Returns (logits (B,1,V), new_state)."""
        cfg = self.cfg
        # NOTE: for enc-dec decode the cross K/V already live in the state;
        # no encoder pass here.
        shared = params.get("shared") if self.has_shared else None
        x = self._embed(params, tokens)
        kinds = dict(zip(self.pattern_names, cfg.pattern))

        def body(carry, xs):
            x, shared, pos = carry
            bp_slice, st_slice = xs
            c = Ctx(shared=None if isinstance(shared, jax.Array) else shared,
                    position=pos)
            new_states = {}
            for name in self.pattern_names:
                x, st = block_decode(kinds[name], bp_slice[name], x,
                                     st_slice[name], cfg, c)
                new_states[name] = st
            return (x, shared, pos), new_states

        shared0 = shared if shared is not None else jnp.float32(0)
        (x, _, _), new_pattern = instrumented_scan(
            body, (x, shared0, jnp.asarray(position, jnp.int32)),
            (params["pattern"], state["pattern"]), name="decode_layers",
            logical_axes=((Ax(("batch", None, "embed")), self._shared_axes(),
                           AX0),
                          (self._unit_axes(), self._unit_state_axes())))
        out = {"pattern": new_pattern}
        if cfg.tail:
            ctx = Ctx(shared=shared, position=jnp.asarray(position, jnp.int32))
            tail_states = {}
            for name, kind in zip(self.tail_names, cfg.tail):
                x, st = block_decode(kind, params["tail"][name], x,
                                     state["tail"][name], cfg, ctx)
                tail_states[name] = st
            out["tail"] = tail_states
        return self._logits(params, x), out
