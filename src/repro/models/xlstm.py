"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, true recurrence).

mLSTM is a gated linear-attention recurrence

    C_t = f_t C_{t-1} + i_t v_t k_t^T        (matrix state,  H × P × N)
    n_t = f_t n_{t-1} + i_t k_t              (normalizer,    H × N)
    h_t = o_t ⊙ (C_t q_t) / max(|n_t·q_t|, 1)

computed here in the *chunkwise-parallel stabilized* form (quadratic within a
chunk, linear recurrence over chunk states — the same HBM-friendly structure
as the SSD kernel; the inter-chunk scan is roofline-instrumented).  All gate
math is fp32 with a running log-scale stabilizer ``m`` so exp() never
overflows, exactly as in the xLSTM paper's Appendix.

sLSTM keeps per-unit scalar state with *recurrent* gate connections
(block-diagonal per head), which forces a sequential time scan — that scan is
the architectural point of sLSTM (state tracking beyond what parallelizable
forms can express), so we implement it faithfully with ``instrumented_scan``.

Both give O(1)-per-token decode updates (``*_decode_step``).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .params import ParamDef
from .scan import instrumented_scan
from .sharding import Ax, constrain

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, dt = cfg.d_model, cfg.dtype
    di = 2 * d                     # block expansion factor 2 (xLSTM paper)
    h = cfg.num_heads
    return {
        "up_proj": ParamDef((d, 2 * di), ("embed", "mlp"), dt),
        "wq": ParamDef((di, di), ("mlp", "heads"), dt),
        "wk": ParamDef((di, di), ("mlp", "heads"), dt),
        "wv": ParamDef((di, di), ("mlp", "heads"), dt),
        "w_if": ParamDef((di, 2 * h), ("mlp", "heads"), "float32", scale=0.1),
        "b_if": ParamDef((2 * h,), ("heads",), "float32", init="zeros"),
        "wo": ParamDef((di, di), ("mlp", "heads"), dt),
        "norm": ParamDef((di,), ("mlp",), dt, init="ones"),
        "down_proj": ParamDef((di, d), ("mlp", "embed"), dt),
    }


def _mlstm_project(params, xin, cfg):
    di = 2 * cfg.d_model
    h = cfg.num_heads
    p = di // h
    up = jnp.einsum("bsd,de->bse", xin, params["up_proj"])
    xm, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", xm, params["wq"]).reshape(*xm.shape[:2], h, p)
    k = jnp.einsum("bse,ef->bsf", xm, params["wk"]).reshape(*xm.shape[:2], h, p)
    v = jnp.einsum("bse,ef->bsf", xm, params["wv"]).reshape(*xm.shape[:2], h, p)
    k = k / jnp.sqrt(jnp.float32(p)).astype(k.dtype)
    gates = (
        jnp.einsum("bse,ef->bsf", xm.astype(jnp.float32), params["w_if"])
        + params["b_if"]
    )
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)       # (B,S,H) each
    o_gate = jax.nn.sigmoid(
        jnp.einsum("bse,ef->bsf", xm, params["wo"]).reshape(*xm.shape[:2], h, p)
    )
    return xm, z, q, k, v, i_raw, f_raw, o_gate


def _mlstm_finish(params, htilde, o_gate, z, xin, cfg):
    b, s = xin.shape[:2]
    di = 2 * cfg.d_model
    y = (htilde * o_gate.astype(jnp.float32)).reshape(b, s, di).astype(xin.dtype)
    # group-norm per head is approximated with a full RMS norm over di
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(
        xin.dtype
    ) * params["norm"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["down_proj"])
    return constrain(out, "batch", "seq", "embed")


def mlstm_chunked(
    q: jax.Array,       # (B, S, H, P) fp any
    k: jax.Array,       # (B, S, H, P)
    v: jax.Array,       # (B, S, H, P)
    i_raw: jax.Array,   # (B, S, H) fp32 log input gate pre-activation
    f_raw: jax.Array,   # (B, S, H) fp32 forget gate pre-activation
    chunk: int,
    state: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    """Stabilized chunkwise mLSTM.  Returns (h̃ (B,S,H,P), (C, n, m)).

    State convention: ``C``/``n`` are stored *descaled* — the true state is
    ``C · exp(m)`` — so all stored magnitudes stay O(1).
    """
    bsz, s, h, p = q.shape
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    lf = jax.nn.log_sigmoid(f_raw)                    # (B,S,H)
    li = i_raw

    def split(t):  # (B,S,...) -> (NC, B, chunk, ...)
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lfc, lic = split(q), split(k), split(v), split(lf), split(li)

    csum = jnp.cumsum(lfc, axis=2)                    # inclusive within chunk
    total = csum[:, :, -1, :]                         # (NC,B,H)

    # log weight of source position s seen from chunk end: li_s + Σ_{r>s} lf_r
    w_src = lic + total[:, :, None, :] - csum         # (NC,B,chunk,H)
    m_src = jnp.max(w_src, axis=2)                    # (NC,B,H)

    # ---- inter-chunk recurrence over (C, n, m) -----------------------------
    if state is None:
        c0 = jnp.zeros((bsz, h, p, p), jnp.float32)
        n0 = jnp.zeros((bsz, h, p), jnp.float32)
        m0 = jnp.full((bsz, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    # per-chunk summaries entering the scan
    def body(carry, inp):
        c_in, n_in, m_in = carry
        k_c, v_c, w_c, m_srcc, tot = inp              # chunk tensors
        entry = (c_in, n_in, m_in)
        m_out = jnp.maximum(m_in + tot, m_srcc)       # (B,H)
        scale_old = jnp.exp(m_in + tot - m_out)       # (B,H)
        w = jnp.exp(w_c - m_out[:, None, :])          # (B,chunk,H)
        c_new = c_in * scale_old[..., None, None] + jnp.einsum(
            "bsh,bshp,bshn->bhpn", w, v_c, k_c
        )
        n_new = n_in * scale_old[..., None] + jnp.einsum("bsh,bshn->bhn", w, k_c)
        return (c_new, n_new, m_out), entry

    bh = Ax(("batch", "heads"))
    chp = Ax(("batch", None, "heads", None))
    (c_fin, n_fin, m_fin), entries = instrumented_scan(
        body, (c0, n0, m0), (kc, vc, w_src, m_src, total),
        name="mlstm_interchunk",
        logical_axes=(
            (Ax(("batch", "heads", None, None)),
             Ax(("batch", "heads", None)), bh),
            (chp, chp, Ax(("batch", None, "heads")), bh, bh),
        ),
    )
    c_entry, n_entry, m_entry = entries               # (NC,B,...) state *before* chunk

    # ---- within-chunk quadratic part --------------------------------------
    # D[t,s] = Σ_{r≤t} lf_r − Σ_{r≤s} lf_r + li_s  for s ≤ t
    dmat = csum[:, :, :, None, :] - csum[:, :, None, :, :] + lic[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), 0)[None, None, :, :, None]
    dmat = jnp.where(tri, dmat, -jnp.inf)             # (NC,B,q,s,H)
    m_intra = jnp.max(dmat, axis=3)                   # (NC,B,q,H)
    # contribution of the entering state at each position t: m_entry + Σ_{r≤t} lf
    w_inter_log = m_entry[:, :, None, :] + csum       # (NC,B,q,H)
    m_tot = jnp.maximum(m_intra, w_inter_log)
    m_tot = jnp.maximum(m_tot, -1e30)                 # keep finite
    w_intra = jnp.exp(dmat - m_tot[:, :, :, None, :])     # (NC,B,q,s,H)
    w_inter = jnp.exp(w_inter_log - m_tot)                # (NC,B,q,H)

    scores = jnp.einsum("cbqhn,cbshn->cbqsh", qc, kc)
    num = jnp.einsum("cbqsh,cbqsh,cbshp->cbqhp", w_intra, scores, vc)
    num = num + jnp.einsum(
        "cbqh,cbhpn,cbqhn->cbqhp", w_inter, c_entry, qc
    )
    den = jnp.einsum("cbqsh,cbqsh->cbqh", w_intra, scores)
    den = den + jnp.einsum("cbqh,cbhn,cbqhn->cbqh", w_inter, n_entry, qc)
    # stabilized max(|q·n|, 1):  1 in true scale = exp(−m) in stored scale
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))
    htilde = num / den[..., None]
    htilde = htilde.swapaxes(0, 1).reshape(bsz, s, h, p)
    return htilde, (c_fin, n_fin, m_fin)


def mlstm_forward(params, xin, cfg: ArchConfig) -> jax.Array:
    y, _ = mlstm_sequence(params, xin, cfg, state=None)
    return y


def mlstm_sequence(params, xin, cfg: ArchConfig, state):
    xm, z, q, k, v, i_raw, f_raw, o_gate = _mlstm_project(params, xin, cfg)
    chunk = cfg.ssm_chunk or 256
    htilde, state = mlstm_chunked(q, k, v, i_raw, f_raw, chunk, state)
    return _mlstm_finish(params, htilde, o_gate, z, xin, cfg), state


def mlstm_decode_step(params, xin, state, cfg: ArchConfig):
    """xin: (B,1,d); state: (C (B,H,P,P), n (B,H,P), m (B,H))."""
    xm, z, q, k, v, i_raw, f_raw, o_gate = _mlstm_project(params, xin, cfg)
    c_in, n_in, m_in = state
    q1 = q[:, 0].astype(jnp.float32)
    k1 = k[:, 0].astype(jnp.float32)
    v1 = v[:, 0].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_raw[:, 0])              # (B,H)
    li = i_raw[:, 0]
    m_out = jnp.maximum(lf + m_in, li)
    f_s = jnp.exp(lf + m_in - m_out)
    i_s = jnp.exp(li - m_out)
    c_new = c_in * f_s[..., None, None] + i_s[..., None, None] * jnp.einsum(
        "bhp,bhn->bhpn", v1, k1
    )
    n_new = n_in * f_s[..., None] + i_s[..., None] * k1
    num = jnp.einsum("bhpn,bhn->bhp", c_new, q1)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhn,bhn->bh", n_new, q1)), jnp.exp(-m_out)
    )
    htilde = (num / den[..., None])[:, None]          # (B,1,H,P)
    out = _mlstm_finish(params, htilde, o_gate, z, xin, cfg)
    return out, (c_new, n_new, m_out)


def mlstm_reference(q, k, v, i_raw, f_raw) -> jax.Array:
    """O(S·state) sequential oracle (tests only)."""
    bsz, s, h, p = q.shape
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    c = jnp.zeros((bsz, h, p, p), jnp.float32)
    n = jnp.zeros((bsz, h, p), jnp.float32)
    m = jnp.full((bsz, h), -jnp.inf, jnp.float32)
    outs = []
    for t in range(s):
        lf = jax.nn.log_sigmoid(f_raw[:, t])
        li = i_raw[:, t]
        m_new = jnp.maximum(lf + m, li)
        f_s = jnp.exp(lf + m - m_new)
        i_s = jnp.exp(li - m_new)
        c = c * f_s[..., None, None] + i_s[..., None, None] * jnp.einsum(
            "bhp,bhn->bhpn", v[:, t], k[:, t]
        )
        n = n * f_s[..., None] + i_s[..., None] * k[:, t]
        m = m_new
        num = jnp.einsum("bhpn,bhn->bhp", c, q[:, t])
        den = jnp.maximum(jnp.abs(jnp.einsum("bhn,bhn->bh", n, q[:, t])), jnp.exp(-m))
        outs.append(num / den[..., None])
    return jnp.stack(outs, axis=1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, dt = cfg.d_model, cfg.dtype
    h = cfg.num_heads
    p = d // h
    return {
        # input → 4 gate pre-activations (i, f, z, o), each (H, P)
        "w_in": ParamDef((d, 4, h, p), ("embed", None, "heads", "head_dim"), "float32"),
        "b_in": ParamDef((4, h, p), (None, "heads", "head_dim"), "float32", init="zeros"),
        # recurrent block-diagonal per head: h_{t-1} (H,P) → gates (4,H,P)
        "r_gate": ParamDef((4, h, p, p), (None, "heads", "head_dim", None), "float32", scale=0.5),
        "norm": ParamDef((d,), ("embed",), dt, init="ones"),
        "out_proj": ParamDef((d, d), ("embed", "embed"), dt),
    }


def _slstm_cell(pre, state):
    """pre: (B,4,H,P) gate pre-activations (input + recurrent already summed);
    state: (c, n, hprev, m) each (B,H,P)."""
    c, n, _, m = state
    i_raw, f_raw, z_raw, o_raw = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + m, i_raw)
    i_s = jnp.exp(i_raw - m_new)
    f_s = jnp.exp(lf + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return c_new, n_new, h_new, m_new


def slstm_forward(params, xin, cfg: ArchConfig) -> jax.Array:
    y, _ = slstm_sequence(params, xin, cfg, state=None)
    return y


def slstm_sequence(params, xin, cfg: ArchConfig, state):
    b, s, d = xin.shape
    h, p = cfg.num_heads, d // cfg.num_heads
    pre_in = (
        jnp.einsum("bsd,dghp->bsghp", xin.astype(jnp.float32), params["w_in"])
        + params["b_in"]
    )  # (B,S,4,H,P)
    if state is None:
        zeros = jnp.zeros((b, h, p), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, h, p), -jnp.inf, jnp.float32))

    def body(carry, x_t):
        st, r_gate = carry
        rec = jnp.einsum("bhp,ghpq->bghq", st[2], r_gate)
        st = _slstm_cell(x_t + rec, st)
        return (st, r_gate), st[2]

    st_ax = Ax(("batch", "heads", "head_dim"))
    (state, _), hs = instrumented_scan(
        body, (state, params["r_gate"]), pre_in.swapaxes(0, 1),
        name="slstm_time",
        logical_axes=(
            ((st_ax, st_ax, st_ax, st_ax),
             Ax((None, "heads", "head_dim", None))),
            Ax(("batch", None, "heads", "head_dim")),
        ),
    )
    y = hs.swapaxes(0, 1).reshape(b, s, d)            # (B,S,d) fp32
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + cfg.norm_eps)).astype(xin.dtype) * params["norm"]
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    return constrain(out, "batch", "seq", "embed"), state


def slstm_decode_step(params, xin, state, cfg: ArchConfig):
    b, _, d = xin.shape
    pre = (
        jnp.einsum("bsd,dghp->bsghp", xin.astype(jnp.float32), params["w_in"])[:, 0]
        + params["b_in"]
    )
    rec = jnp.einsum("bhp,ghpq->bghq", state[2], params["r_gate"])
    state = _slstm_cell(pre + rec, state)
    y = state[2].reshape(b, 1, d)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + cfg.norm_eps)).astype(xin.dtype) * params["norm"]
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    return out, state
