"""Model substrate: composable blocks for the 10 assigned architectures.

Pure-pytree parameter handling (``params.py``), logical-axis sharding
(``sharding.py``), block library (``layers.py``, ``moe.py``, ``ssm.py``,
``xlstm.py``), and the assembly (``model.py``).
"""

from .config import ArchConfig, get_config, list_configs, register
from .model import Model, padded_vocab

__all__ = ["ArchConfig", "Model", "get_config", "list_configs", "register",
           "padded_vocab"]
