"""Core layers: RMSNorm, RoPE, GQA attention (full / sliding-window / cross),
and gated MLPs.

The attention *reference path* is a memory-efficient chunked implementation
(scan over query chunks — flash-style memory behavior at the XLA level) so
that 32k-token prefills fit HBM without a kernel; the Pallas flash kernel
(``repro.kernels``) replaces it on real TPUs via ``cfg.use_pallas``.

All activations carry logical-axis sharding constraints so that bodies lower
identically whether inside the full model or standalone (roofline tool).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .params import ParamDef
from .scan import instrumented_scan
from .sharding import AX0, Ax, constrain

NEG_INF = -2.0**30  # large-but-finite: avoids NaN from all-masked rows


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_def(d: int, dtype: str) -> ParamDef:
    return ParamDef(shape=(d,), axes=("embed",), dtype=dtype, init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention parameter defs
# ---------------------------------------------------------------------------

def attention_defs(cfg: ArchConfig, *, cross: bool = False) -> Dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    dt = cfg.dtype
    defs: Dict[str, ParamDef] = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim"), dt),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed"), dt),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef((h, hd), ("heads", "head_dim"), dt, init="zeros")
        defs["bk"] = ParamDef((kv, hd), ("kv_heads", "head_dim"), dt, init="zeros")
        defs["bv"] = ParamDef((kv, hd), ("kv_heads", "head_dim"), dt, init="zeros")
    return defs


def _project_qkv(
    params: Dict[str, jax.Array],
    xq: jax.Array,
    xkv: jax.Array,
    cfg: ArchConfig,
    q_positions: jax.Array,
    kv_positions: Optional[jax.Array],
    *,
    rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    # constrain BEFORE rope as well as after: otherwise GSPMD propagation
    # invents partial shardings for the projection outputs and pays
    # full-replication reshards at the rope split/concat ops.
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    if rope:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        if kv_positions is not None:
            k = apply_rope(k, kv_positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return scores
    return cap * jnp.tanh(scores / cap)


# ---------------------------------------------------------------------------
# chunked (memory-efficient) attention — the XLA reference path
# ---------------------------------------------------------------------------

def _attend_chunk(
    q: jax.Array,          # (B, Cq, KV, G, hd) one query chunk, grouped
    k: jax.Array,          # (B, S, KV, hd)
    v: jax.Array,          # (B, S, KV, hd)
    q_start: jax.Array,    # global position of the chunk's first query:
                           # scalar, or (B,) when every batch row sits at its
                           # own position (continuous-batching decode)
    *,
    causal: bool,
    window: int,
    softcap: float,
    kv_valid_len: Optional[jax.Array],   # scalar or (B,)
) -> jax.Array:
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqngk,bsnk->bngqs", q, k).astype(jnp.float32) * scale
    scores = _softcap(scores, softcap)
    s_len = k.shape[1]
    q_start = jnp.asarray(q_start)
    # q_pos: (q,) for a shared scalar start, (B, q) for per-row starts
    q_pos = q_start[..., None] + jnp.arange(q.shape[1])
    k_pos = jnp.arange(s_len)
    mask = jnp.ones(q_pos.shape + (s_len,), dtype=bool)
    if causal:
        mask &= q_pos[..., None] >= k_pos
    if window > 0:
        mask &= q_pos[..., None] - k_pos < window
    if kv_valid_len is not None:
        valid = jnp.asarray(kv_valid_len)
        if valid.ndim:                       # (B,) per-row valid prefixes
            mask = mask & (k_pos < valid[:, None, None])
        else:
            mask = mask & (k_pos < valid)
    if mask.ndim == 3:                       # (B, q, s) → (B, 1, 1, q, s)
        mask = mask[:, None, None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bngqs,bsnk->bqngk", probs, v)


def multi_head_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ArchConfig,
    *,
    causal: bool = True,
    window: int = 0,
    xkv: Optional[jax.Array] = None,
    rope: bool = True,
    q_chunk: Optional[int] = None,
) -> jax.Array:
    """Full-sequence attention (training / prefill).

    GQA: queries grouped as (KV, G) so each KV head serves G query heads.
    Scans over query chunks so peak score memory is O(q_chunk · S).
    """
    b, s, _ = x.shape
    kv_src = xkv if xkv is not None else x
    positions = jnp.arange(s)
    kv_positions = None if xkv is not None else positions
    q, k, v = _project_qkv(
        params, x, kv_src, cfg, positions, kv_positions, rope=rope and xkv is None
    )
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = h // kvh
    q = q.reshape(b, s, kvh, g, hd)

    if cfg.use_pallas and xkv is None:
        from repro.kernels.ops import attention as pallas_attention

        qh = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        out = pallas_attention(qh, kh, vh, causal, window, cfg.attn_softcap)
        out = out.transpose(0, 2, 1, 3)
        out = constrain(out, "batch", "seq", "heads", "head_dim")
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
        return constrain(y, "batch", "seq", "embed")

    chunk = min(q_chunk or cfg.attn_q_chunk, s)
    softcap = cfg.attn_softcap
    if s % chunk != 0:
        chunk = s  # irregular sizes: single chunk (smoke tests)

    if chunk == s:
        out = _attend_chunk(
            q, k, v, jnp.int32(0),
            causal=causal, window=window, softcap=softcap, kv_valid_len=None,
        )
    else:
        n_chunks = s // chunk
        q_chunks = q.reshape(b, n_chunks, chunk, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)

        def body(carry, xs):
            k_, v_ = carry
            idx, q_c = xs
            o = _attend_chunk(
                q_c, k_, v_, idx * chunk,
                causal=causal, window=window, softcap=softcap, kv_valid_len=None,
            )
            return carry, o

        kv_ax = Ax(("batch", "seq", "kv_heads", "head_dim"))
        _, outs = instrumented_scan(
            body,
            (k, v),
            (jnp.arange(n_chunks), q_chunks),
            name="attn_q_chunks",
            logical_axes=(
                (kv_ax, kv_ax),
                (AX0, Ax(("batch", None, "kv_heads", "q_per_kv",
                          "head_dim"))),
            ),
        )
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kvh, g, hd)

    out = out.reshape(b, s, h, hd)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (per token × head absmax)
# ---------------------------------------------------------------------------

def kv_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (..., hd) float → (int8 values, f32 scale over the last axis)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0]


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# decode-step attention over a KV cache
# ---------------------------------------------------------------------------

def decode_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,              # (B, 1, d)
    cache_k: jax.Array,        # (B, S_max, KV, hd) — bf16 or int8
    cache_v: jax.Array,
    position: jax.Array,       # scalar int, or (B,) per-row positions
    cfg: ArchConfig,
    *,
    window: int = 0,
    cross: bool = False,
    k_scale: Optional[jax.Array] = None,   # (B, S_max, KV) — int8 caches
    v_scale: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array,
           Optional[jax.Array], Optional[jax.Array]]:
    """One-token decode: append K/V at ``position`` (self-attention) and
    attend over the valid prefix.  For cross-attention the cache is the
    encoder/vision projection and is not updated.  With ``k_scale`` the
    caches are int8 (per token × head absmax) and dequantized on read — on
    TPU the dequant fuses into the attention matmul's cache stream.

    ``position`` may be a (B,) vector for continuous batching, where each
    batch row decodes at its own offset.  A per-row position of ``S_max``
    (the cache length) is a write-proof sentinel: the masked row write
    touches nothing and the row attends over an empty prefix, which lets a
    fixed-slot engine run free slots through the same jitted step."""
    b = x.shape[0]
    position = jnp.asarray(position, dtype=jnp.int32)
    per_row = position.ndim == 1
    if per_row:
        positions = position[:, None]                     # (B, 1)
    else:
        positions = jnp.full((b, 1), position, dtype=jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    if not cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if "bk" in params:
            k_new = k_new + params["bk"]
            v_new = v_new + params["bv"]
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
        if per_row:
            # each row scatters into its own cache slot; the free-slot
            # sentinel (position == S_max) is out of bounds and drops —
            # an O(B) scatter, not an O(B*S_max) masked rewrite
            rows = jnp.arange(b, dtype=jnp.int32)
            put4 = lambda cache, new: cache.at[rows, position].set(
                new[:, 0].astype(cache.dtype), mode="drop")
            put3 = lambda cache, new: cache.at[rows, position].set(
                new[:, 0], mode="drop")
            if k_scale is not None:
                k8, ks_new = kv_quantize(k_new)
                v8, vs_new = kv_quantize(v_new)
                cache_k = put4(cache_k, k8)
                cache_v = put4(cache_v, v8)
                k_scale = put3(k_scale, ks_new)
                v_scale = put3(v_scale, vs_new)
            else:
                cache_k = put4(cache_k, k_new)
                cache_v = put4(cache_v, v_new)
        elif k_scale is not None:
            k8, ks_new = kv_quantize(k_new)
            v8, vs_new = kv_quantize(v_new)
            cache_k = jax.lax.dynamic_update_slice(
                cache_k, k8, (0, position, 0, 0))
            cache_v = jax.lax.dynamic_update_slice(
                cache_v, v8, (0, position, 0, 0))
            k_scale = jax.lax.dynamic_update_slice(
                k_scale, ks_new, (0, position, 0))
            v_scale = jax.lax.dynamic_update_slice(
                v_scale, vs_new, (0, position, 0))
        else:
            cache_k = jax.lax.dynamic_update_slice(
                cache_k, k_new.astype(cache_k.dtype), (0, position, 0, 0)
            )
            cache_v = jax.lax.dynamic_update_slice(
                cache_v, v_new.astype(cache_v.dtype), (0, position, 0, 0)
            )
        cache_k = constrain(cache_k, "cache_batch", "cache_seq", "kv_heads", "head_dim")
        cache_v = constrain(cache_v, "cache_batch", "cache_seq", "kv_heads", "head_dim")
        valid_len = position + 1
    else:
        valid_len = None

    if k_scale is not None:
        k_eff = kv_dequantize(cache_k, k_scale, x.dtype)
        v_eff = kv_dequantize(cache_v, v_scale, x.dtype)
    else:
        k_eff, v_eff = cache_k, cache_v

    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = h // kvh
    q = q.reshape(b, 1, kvh, g, hd)
    if not cross and window > 0:
        # sliding window: positions ≤ pos−window are masked inside the chunk
        out = _attend_chunk(
            q, k_eff, v_eff, position,
            causal=True, window=window, softcap=cfg.attn_softcap,
            kv_valid_len=valid_len,
        )
    else:
        out = _attend_chunk(
            q, k_eff, v_eff, position if not cross else jnp.int32(0),
            causal=not cross, window=0, softcap=cfg.attn_softcap,
            kv_valid_len=valid_len,
        )
    out = out.reshape(b, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, "batch", None, "embed"), cache_k, cache_v, \
        k_scale, v_scale


def prefill_kv(
    params: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ArchConfig,
    cache_len: int,
) -> Tuple[jax.Array, jax.Array]:
    """Project K/V for a whole prompt into a fresh cache of ``cache_len``."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    k = apply_rope(k, positions, cfg.rope_theta)
    pad = [(0, 0), (0, cache_len - s), (0, 0), (0, 0)]
    return jnp.pad(k, pad), jnp.pad(v, pad)


def prefill_chunk_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,              # (B, C, d) — one prompt chunk
    cache_k: jax.Array,        # (B, S_max, KV, hd) — bf16 or int8
    cache_v: jax.Array,
    offset: jax.Array,         # scalar int: global position of chunk row 0
    cfg: ArchConfig,
    *,
    window: int = 0,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array,
           Optional[jax.Array], Optional[jax.Array]]:
    """Chunked prefill into an existing decode cache: project/rope the
    chunk at positions ``[offset, offset+C)``, write its K/V into the
    cache, and attend the chunk causally over the cache prefix.  Shapes
    are fixed by (B, C, S_max), so a continuous-batching engine can feed
    prompts of any length through one jitted call.  The chunk write must
    stay in bounds (``offset + C <= S_max``); padded rows past the prompt
    end are masked out by causality for this chunk and overwritten by the
    decode loop before they ever enter the valid prefix."""
    b, c, _ = x.shape
    positions = offset + jnp.arange(c, dtype=jnp.int32)[None, :]  # (1, C)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k_new = k_new + params["bk"]
        v_new = v_new + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    if k_scale is not None:
        k8, ks_new = kv_quantize(k_new)
        v8, vs_new = kv_quantize(v_new)
        cache_k = jax.lax.dynamic_update_slice(cache_k, k8, (0, offset, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v8, (0, offset, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(k_scale, ks_new, (0, offset, 0))
        v_scale = jax.lax.dynamic_update_slice(v_scale, vs_new, (0, offset, 0))
        k_eff = kv_dequantize(cache_k, k_scale, x.dtype)
        v_eff = kv_dequantize(cache_v, v_scale, x.dtype)
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, offset, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, offset, 0, 0))
        k_eff, v_eff = cache_k, cache_v
    cache_k = constrain(cache_k, "cache_batch", "cache_seq", "kv_heads", "head_dim")
    cache_v = constrain(cache_v, "cache_batch", "cache_seq", "kv_heads", "head_dim")
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = h // kvh
    q = q.reshape(b, c, kvh, g, hd)
    out = _attend_chunk(
        q, k_eff, v_eff, offset,
        causal=True, window=window, softcap=cfg.attn_softcap,
        kv_valid_len=offset + c,
    )
    out = out.reshape(b, c, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, "batch", "seq", "embed"), cache_k, cache_v, \
        k_scale, v_scale


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d, f, dt = cfg.d_model, d_ff or cfg.d_ff, cfg.dtype
    defs = {
        "w1": ParamDef((d, f), ("embed", "mlp"), dt),
        "w2": ParamDef((f, d), ("mlp", "embed"), dt),
    }
    if cfg.act in ("silu", "geglu"):  # gated variants need a third matrix
        defs["w3"] = ParamDef((d, f), ("embed", "mlp"), dt)
    return defs


def _activate(x: jax.Array, act: str) -> jax.Array:
    if act in ("silu",):
        return jax.nn.silu(x)
    if act in ("gelu", "geglu"):
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {act!r}")


def mlp(params: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["w1"])
    h = _activate(h, act)
    if "w3" in params:
        h = h * jnp.einsum("bsd,df->bsf", x, params["w3"])
    h = constrain(h, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, params["w2"])
    return constrain(y, "batch", "seq", "embed")
