"""Mixture-of-Experts FFN with capacity-based gather dispatch.

TPU-native dispatch (see DESIGN.md hardware-adaptation notes): instead of the
GShard one-hot dispatch einsum — whose (tokens × experts × capacity) tensors
dominate compiled FLOPs and would wreck the MODEL_FLOPS/HLO_FLOPs ratio — we

  1. route: top-k experts per token (router in fp32),
  2. per-expert token selection: top-C over the (experts, tokens) score
     matrix ⇒ an (E, C) int32 gather index (C = tokens·k/E · capacity_factor),
  3. gather tokens to (E, C, d), run the expert FFN as one batched einsum
     (MXU-shaped), and
  4. scatter-add back weighted by gate probabilities.

FLOPs are proportional to actual expert compute (k·cf × dense-equivalent);
the only O(E·T) object is the fp32 routing matrix, which shards over
(experts→model, tokens→data).  Exact (vs the dense reference in
``moe_reference``) whenever no token overflows capacity.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import _activate
from .params import ParamDef
from .sharding import constrain


def moe_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, e, f, dt = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff, cfg.dtype
    # expert tensors use their own logical d_model axis ("expert_embed") so
    # their 2-D (experts×data) sharding is controllable independently of the
    # dense params' FSDP axis (rule dedup would otherwise couple them).
    defs = {
        "router": ParamDef((d, e), ("embed", "experts"), "float32", scale=0.1),
        "w1": ParamDef((e, d, f), ("experts", "expert_embed", "expert_mlp"), dt),
        "w2": ParamDef((e, f, d), ("experts", "expert_mlp", "expert_embed"), dt),
    }
    if cfg.act == "silu":
        defs["w3"] = ParamDef((e, d, f), ("experts", "expert_embed", "expert_mlp"), dt)
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        defs["shared_w1"] = ParamDef((d, fs), ("embed", "mlp"), dt)
        defs["shared_w2"] = ParamDef((fs, d), ("mlp", "embed"), dt)
        if cfg.act == "silu":
            defs["shared_w3"] = ParamDef((d, fs), ("embed", "mlp"), dt)
    return defs


def _router_probs(
    params: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig
) -> jax.Array:
    # NB: no x.astype(f32) — that materializes a full fp32 copy of the token
    # array, which GSPMD then reshards at 2× the bytes (measured: 28 GiB of
    # fp32 all-gathers per layer on kimi-k2).  Mixed-precision einsum with a
    # fp32 accumulator gives the same numerics for the router.
    logits = jnp.einsum("td,de->te", x, params["router"],
                        preferred_element_type=jnp.float32)
    return jax.nn.softmax(logits, axis=-1)  # (T, E)


def moe_ffn(
    params: Dict[str, jax.Array],
    x: jax.Array,          # (B, S, d)
    cfg: ArchConfig,
    *,
    capacity_factor: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, router aux loss).

    Dispatch paths (``cfg.moe_dispatch_groups``):
      0/1 — global GShard-style top-C gather dispatch (baseline);
      g>1 — per-group routing aligned with the data axis;
      -1  — shard_map expert parallelism: explicit all_to_all dispatch,
            per-shard capacity, per-layer expert-weight all-gather (ZeRO)
            — the §Perf winner for large MoE (see EXPERIMENTS.md).
    """
    g = cfg.moe_dispatch_groups
    if g == -1:
        from .sharding import _state

        mesh = getattr(_state, "mesh", None)
        if mesh is not None and "model" in mesh.axis_names \
                and cfg.num_experts % mesh.shape["model"] == 0:
            return _moe_ffn_shard_map(params, x, cfg,
                                      capacity_factor or cfg.capacity_factor)
        g = 0  # no mesh (smoke tests): fall through to the global path
    if g > 1 and (x.shape[0] * x.shape[1]) % g == 0:
        return _moe_ffn_grouped(params, x, cfg, g,
                                capacity_factor or cfg.capacity_factor)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(t, d)
    xt = constrain(xt, "batch", "embed")

    probs = _router_probs(params, xt, cfg)                       # (T, E)
    probs = constrain(probs, "batch", "experts")
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # (T, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )  # renormalize over selected experts

    # load-balance aux loss (Switch-style): E · Σ_e fraction_e · prob_e
    sel_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (T, k, E)
    token_frac = sel_onehot.sum(axis=(0, 1)) / (t * k)
    prob_frac = probs.mean(axis=0)
    aux = e * jnp.sum(token_frac * prob_frac)

    cf = capacity_factor or cfg.capacity_factor
    capacity = max(1, min(t, int(t * k * cf / e) + 1))

    # per-expert selection scores: prob if the expert was chosen, else -inf
    chosen = sel_onehot.sum(axis=1)                               # (T, E) 0/1
    combine = (gate_vals[:, :, None] * sel_onehot).sum(axis=1)    # (T, E)
    sel_scores = jnp.where(chosen > 0, probs, -jnp.inf).T         # (E, T)
    sel_scores = constrain(sel_scores, "experts", "batch")
    top_scores, token_idx = jax.lax.top_k(sel_scores, capacity)   # (E, C)
    valid = jnp.isfinite(top_scores)                              # dropped?
    token_idx = jnp.where(valid, token_idx, 0)

    xs = jnp.take(xt, token_idx.reshape(-1), axis=0)
    xs = xs.reshape(e, capacity, d)
    xs = constrain(xs, "experts", None, "embed")

    h = jnp.einsum("ecd,edf->ecf", xs, params["w1"])
    h = _activate(h, cfg.act)
    if "w3" in params:
        h = h * jnp.einsum("ecd,edf->ecf", xs, params["w3"])
    h = constrain(h, "experts", None, "expert_mlp")
    ys = jnp.einsum("ecf,efd->ecd", h, params["w2"])              # (E, C, d)

    # combine: weight by gate prob, zero dropped slots, scatter-add
    w = jnp.take_along_axis(combine.T, token_idx, axis=1)         # (E, C)
    ys = ys * (w * valid).astype(ys.dtype)[..., None]
    out = jnp.zeros((t, d), ys.dtype).at[token_idx.reshape(-1)].add(
        ys.reshape(-1, d)
    )
    out = constrain(out, "batch", "embed")

    if cfg.num_shared_experts:
        hs = jnp.einsum("td,df->tf", xt, params["shared_w1"])
        hs = _activate(hs, cfg.act)
        if "shared_w3" in params:
            hs = hs * jnp.einsum("td,df->tf", xt, params["shared_w3"])
        out = out + jnp.einsum("tf,fd->td", hs, params["shared_w2"])

    return out.reshape(b, s, d), aux.astype(jnp.float32)


def _moe_ffn_grouped(
    params: Dict[str, jax.Array],
    x: jax.Array,          # (B, S, d)
    cfg: ArchConfig,
    g: int,
    cf: float,
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel dispatch: tokens are routed *within* g groups that
    align with the data mesh axis, so the token gather/scatter is
    shard-local; only the dispatched (E, C, d) copies cross the mesh (the
    EP all-to-all), never the full (T, d) token array.

    Semantics: identical routing, but capacity is enforced *per group*
    (standard per-device capacity in EP systems) — exact vs the dense
    reference whenever no group overflows.
    """
    b, s, d = x.shape
    t = b * s
    tl = t // g
    e, k = cfg.num_experts, cfg.experts_per_token
    xg = x.reshape(g, tl, d)
    xg = constrain(xg, "batch", None, "embed")

    logits = jnp.einsum("gtd,de->gte", xg, params["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (g, tl, E)
    probs = constrain(probs, "batch", None, "experts")
    gate_vals, gate_idx = jax.lax.top_k(probs, k)        # (g, tl, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    sel_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (g,tl,k,E)
    token_frac = sel_onehot.sum(axis=(0, 1, 2)) / (t * k)
    prob_frac = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(token_frac * prob_frac)

    capacity = max(1, min(tl, int(tl * k * cf / e) + 1))
    chosen = sel_onehot.sum(axis=2)                      # (g, tl, E)
    combine = (gate_vals[..., None] * sel_onehot).sum(axis=2)  # (g, tl, E)
    sel_scores = jnp.where(chosen > 0, probs, -jnp.inf)  # (g, tl, E)
    sel_scores = sel_scores.swapaxes(1, 2)               # (g, E, tl)
    sel_scores = constrain(sel_scores, "batch", "experts", None)
    top_scores, token_idx = jax.lax.top_k(sel_scores, capacity)  # (g,E,C)
    valid = jnp.isfinite(top_scores)
    token_idx = jnp.where(valid, token_idx, 0)
    token_idx = constrain(token_idx, "batch", None, None)

    # shard-local gather: (g, E·C, d), g stays on the data axis
    xs = jnp.take_along_axis(
        xg, token_idx.reshape(g, e * capacity)[..., None], axis=1)
    xs = constrain(xs, "batch", None, "embed")
    xs = xs.reshape(g, e, capacity, d).swapaxes(0, 1)    # (E, g, C, d)
    xs = constrain(xs, "experts", "batch", None, "embed")

    h = jnp.einsum("egcd,edf->egcf", xs, params["w1"])
    h = _activate(h, cfg.act)
    if "w3" in params:
        h = h * jnp.einsum("egcd,edf->egcf", xs, params["w3"])
    h = constrain(h, "experts", "batch", None, "expert_mlp")
    ys = jnp.einsum("egcf,efd->egcd", h, params["w2"])   # (E, g, C, d)

    w = jnp.take_along_axis(combine.swapaxes(1, 2), token_idx, axis=2)
    ys = ys * (w.swapaxes(0, 1) * valid.swapaxes(0, 1)).astype(
        ys.dtype)[..., None]
    ys = ys.swapaxes(0, 1)                               # (g, E, C, d)
    out = jnp.zeros((g, tl, d), ys.dtype).at[
        jnp.arange(g)[:, None], token_idx.reshape(g, -1)
    ].add(ys.reshape(g, -1, d))
    out = constrain(out, "batch", None, "embed")

    if cfg.num_shared_experts:
        hs = jnp.einsum("gtd,df->gtf", xg, params["shared_w1"])
        hs = _activate(hs, cfg.act)
        if "shared_w3" in params:
            hs = hs * jnp.einsum("gtd,df->gtf", xg, params["shared_w3"])
        out = out + jnp.einsum("gtf,fd->gtd", hs, params["shared_w2"])

    return out.reshape(b, s, d), aux.astype(jnp.float32)


def _moe_ffn_shard_map(
    params: Dict[str, jax.Array],
    x: jax.Array,          # (B, S, d)
    cfg: ArchConfig,
    cf: float,
) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism with explicit collectives (shard_map).

    Per (data-row, model-col) chip:
      1. route the chip's own tokens (router weights are replicated, fp32);
      2. per-shard capacity top-C selection and local gather → (E, C, d);
      3. ``all_to_all`` over the model axis → (E/tp, C·tp, d): each chip
         receives its experts' tokens — the only token bytes that move are
         the dispatched copies (k·cf per token), never the full array;
      4. expert weights (stored experts×expert_embed-sharded, ZeRO-style)
         are ``all_gather``-ed over the data axes once per layer;
      5. batched expert FFN, reverse ``all_to_all``, local weighted combine.

    GSPMD's gather/scatter lowering of the same computation produced
    ~57 GiB/layer of fp32 all-reduces (see EXPERIMENTS.md §Perf, kimi-k2
    iterations 1–2); the explicit form moves ~100× less.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from .sharding import _state, logical_to_spec

    mesh = _state.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = mesh.shape["model"]
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    e_loc = e // tp

    # the residual stream enters sequence-parallel (seq → model under the
    # train/prefill rules): each model chip routes its own seq slice — the
    # dispatch work itself is model-partitioned, not replicated.
    x_spec = logical_to_spec(("batch", "seq", "embed"))
    if s % tp != 0 or (x_spec[1] is None and tp > 1 and s > 1):
        # no SP available (e.g. odd seq): fall back to batch-only sharding
        x_spec = P(x_spec[0], None, None)
    defs = moe_defs(cfg)
    w_names = ["router", "w1", "w2"] + (["w3"] if "w3" in params else [])
    # router (d×E fp32, ~10 MB) is replicated into the body; expert tensors
    # enter with their stored (experts × expert_embed) sharding.
    w_specs = [P() if n == "router" else logical_to_spec(defs[n].axes)
               for n in w_names]
    w_args = [params[n] for n in w_names]

    def body(xl, router, w1, w2, *rest):
        w3 = rest[0] if rest else None
        bl, sl, _ = xl.shape
        tl = bl * sl
        xt = xl.reshape(tl, d)
        logits = jnp.einsum("td,de->te", xt, router,
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)               # (tl, E)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                         1e-9)
        sel_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
        token_frac = sel_onehot.sum(axis=(0, 1)) / (tl * k)
        prob_frac = probs.mean(axis=0)
        aux = e * jnp.sum(token_frac * prob_frac)
        mean_axes = dp_axes + (("model",) if x_spec[1] is not None else ())
        aux = jax.lax.pmean(aux, mean_axes) if mean_axes else aux

        capacity = max(1, min(tl, int(tl * k * cf / e) + 1))
        chosen = sel_onehot.sum(axis=1)                       # (tl, E)
        combine = (gate_vals[:, :, None] * sel_onehot).sum(axis=1)
        sel_scores = jnp.where(chosen > 0, probs, -jnp.inf).T  # (E, tl)
        top_scores, token_idx = jax.lax.top_k(sel_scores, capacity)
        valid = jnp.isfinite(top_scores)
        token_idx = jnp.where(valid, token_idx, 0)

        xs = jnp.take(xt, token_idx.reshape(-1), axis=0)
        xs = xs.reshape(e, capacity, d)
        # dispatch: tokens → their experts' chips (model axis)
        xs = jax.lax.all_to_all(xs, "model", split_axis=0, concat_axis=1,
                                tiled=True)                   # (E/tp, C·tp, d)
        # ZeRO weight gather over the data axes (expert_embed-sharded)
        w1f = jax.lax.all_gather(w1, dp_axes, axis=1, tiled=True) \
            if dp_axes else w1                                # (E/tp, d, f)
        w2f = jax.lax.all_gather(w2, dp_axes, axis=2, tiled=True) \
            if dp_axes else w2                                # (E/tp, f, d)
        h = jnp.einsum("ecd,edf->ecf", xs, w1f)
        h = _activate(h, cfg.act)
        if w3 is not None:
            w3f = jax.lax.all_gather(w3, dp_axes, axis=1, tiled=True) \
                if dp_axes else w3
            h = h * jnp.einsum("ecd,edf->ecf", xs, w3f)
        ys = jnp.einsum("ecf,efd->ecd", h, w2f)               # (E/tp, C·tp, d)
        # return: expert outputs → token-owner chips
        ys = jax.lax.all_to_all(ys, "model", split_axis=1, concat_axis=0,
                                tiled=True)                   # (E, C, d)
        w = jnp.take_along_axis(combine.T, token_idx, axis=1)  # (E, C)
        ys = ys * (w * valid).astype(ys.dtype)[..., None]
        out = jnp.zeros((tl, d), ys.dtype).at[
            token_idx.reshape(-1)].add(ys.reshape(-1, d))
        return out.reshape(bl, sl, d), aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, *w_specs),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, *w_args)

    if cfg.num_shared_experts:
        bsz, sl, _ = x.shape
        xt = x.reshape(bsz * sl, d)
        hs = jnp.einsum("td,df->tf", xt, params["shared_w1"])
        hs = _activate(hs, cfg.act)
        if "shared_w3" in params:
            hs = hs * jnp.einsum("td,df->tf", xt, params["shared_w3"])
        out = out + jnp.einsum("tf,fd->td", hs,
                               params["shared_w2"]).reshape(bsz, sl, d)
    return out, aux.astype(jnp.float32)


def moe_reference(
    params: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig
) -> jax.Array:
    """Dense-masked oracle: every expert sees every token, masked combine.
    O(T·E·d·f) — tests only."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    probs = _router_probs(params, xt, cfg)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    combine = jnp.zeros_like(probs)
    for j in range(cfg.experts_per_token):
        combine = combine.at[jnp.arange(t), gate_idx[:, j]].add(gate_vals[:, j])
    h = jnp.einsum("td,edf->etf", xt, params["w1"])
    h = _activate(h, cfg.act)
    if "w3" in params:
        h = h * jnp.einsum("td,edf->etf", xt, params["w3"])
    ys = jnp.einsum("etf,efd->etd", h, params["w2"])
    out = jnp.einsum("etd,te->td", ys, combine.astype(ys.dtype))
    if cfg.num_shared_experts:
        hs = jnp.einsum("td,df->tf", xt, params["shared_w1"])
        hs = _activate(hs, cfg.act)
        if "shared_w3" in params:
            hs = hs * jnp.einsum("td,df->tf", xt, params["shared_w3"])
        out = out + jnp.einsum("tf,fd->td", hs, params["shared_w2"])
    return out.reshape(b, s, d)
