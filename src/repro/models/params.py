"""Parameter trees: declarative specs → abstract / sharded / materialized.

Models declare parameters as trees of ``ParamDef`` (shape, dtype, logical
axes, init scale).  From one spec tree we derive:

* ``abstract(tree)``      — ShapeDtypeStructs (dry-run lowering, no memory);
* ``specs(tree)``         — PartitionSpecs via the active sharding rules;
* ``initialize(key, tree)`` — materialized arrays (smoke tests / examples).

No Flax; pure pytrees, so everything composes with jax.jit/shard_map and the
AFT checkpoint layer (which persists leaves as versioned storage keys).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import logical_to_spec

PyTree = Any


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: str = "bfloat16"
    init: str = "fan_in"      # fan_in | zeros | ones | normal | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def abstract(tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        tree,
        is_leaf=is_def,
    )


def specs(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda d: logical_to_spec(d.axes), tree, is_leaf=is_def)


def axes_tree(tree: PyTree) -> PyTree:
    """ParamDef tree → Ax tree (roofline body-input shardings)."""
    from .sharding import Ax

    return jax.tree.map(lambda d: Ax(d.axes), tree, is_leaf=is_def)


def _init_leaf(key: jax.Array, d: ParamDef) -> jax.Array:
    dtype = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (d.scale * jax.random.normal(key, d.shape)).astype(dtype)
    if d.init == "embed":
        return (d.scale * jax.random.normal(key, d.shape)).astype(dtype)
    # fan_in (LeCun-ish): scale by the contracting dimension — for stacked
    # layer params the leading "layers" axis is excluded from fan-in.
    shape = d.shape
    fan_axes = [s for s, a in zip(shape, d.axes) if a not in ("layers",)]
    fan_in = fan_axes[0] if fan_axes else 1
    std = d.scale / np.sqrt(max(1, fan_in))
    return (std * jax.random.normal(key, d.shape)).astype(dtype)


def initialize(key: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_def)
    out = []
    for i, d in enumerate(leaves):
        out.append(_init_leaf(jax.random.fold_in(key, i), d))
    return jax.tree.unflatten(treedef, out)


def count_params(tree: PyTree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_def)
    total = 0
    for leaf in leaves:
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        total += int(np.prod(shape)) if shape else 1
    return total


def param_bytes(tree: PyTree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_def)
    total = 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        itemsize = jnp.dtype(getattr(leaf, "dtype", "bfloat16")).itemsize
        total += n * itemsize
    return total
