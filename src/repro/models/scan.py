"""Instrumented ``lax.scan`` for scan-aware roofline accounting.

XLA's ``cost_analysis`` counts a while-loop body exactly once regardless of
trip count.  Every scan in the model stack therefore goes through
``instrumented_scan``: when a ``ScanCollector`` is active (roofline tracing),
the wrapper records the body function plus the exact carry/x abstract values
and trip count, building a tree of nested scans.  The roofline tool then
lowers each body *separately* under the same mesh and applies

    corrected(node) = cost(node) + Σ_child [ len(child)·corrected(child)
                                             − cost(child) ]

recursively (see launch/roofline.py), recovering true whole-program costs.

Bodies must take all tensor inputs through ``carry``/``xs`` (no tracer
closures) — model code threads shared/unstacked weights through the carry.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp

_state = threading.local()


@dataclass
class ScanRecord:
    name: str
    body: Callable
    carry_sds: Any
    x_sds: Any          # one slice of xs (leading axis removed); None if no xs
    length: int
    children: List["ScanRecord"] = field(default_factory=list)
    # logical sharding axes for (carry, x-slice): pytrees matching
    # carry/x_sds whose leaves are tuples of logical axis names (() for
    # replicated/scalar).  The roofline tool lowers bodies with the true
    # per-chip input shardings derived from these.
    logical_axes: Any = None


class ScanCollector:
    """Context manager that gathers the scan tree during a trace."""

    def __init__(self) -> None:
        self.root = ScanRecord("<root>", None, None, None, 1)

    def __enter__(self) -> "ScanCollector":
        _state.stack = [self.root]
        return self

    def __exit__(self, *exc) -> None:
        del _state.stack


def _sds(x: Any) -> Any:
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)), x
    )


def instrumented_scan(
    body: Callable,
    carry: Any,
    xs: Any = None,
    *,
    length: Optional[int] = None,
    name: str = "scan",
    unroll: int = 1,
    logical_axes: Any = None,
):
    stack = getattr(_state, "stack", None)
    if stack is None:
        return jax.lax.scan(body, carry, xs, length=length, unroll=unroll)
    if length is None:
        leaves = jax.tree.leaves(xs)
        if not leaves:
            raise ValueError("instrumented_scan needs xs or length")
        length = leaves[0].shape[0]
    x_slice = (
        None
        if xs is None
        else jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], jnp.result_type(a)), xs
        )
    )
    rec = ScanRecord(name, body, _sds(carry), x_slice, length,
                     logical_axes=logical_axes)
    stack[-1].children.append(rec)
    stack.append(rec)
    try:
        return jax.lax.scan(body, carry, xs, length=length, unroll=unroll)
    finally:
        stack.pop()
