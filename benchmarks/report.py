"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from
benchmarks/results/{dryrun,roofline}.json, plus the routing-policy /
per-node AftNode.stats() table from fig_routing.json.

  PYTHONPATH=src python -m benchmarks.report [--section routing]
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parent / "results"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def dryrun_table() -> str:
    res = json.loads((RESULTS / "dryrun.json").read_text())
    rows = ["| arch | shape | mesh | status | GiB/dev (args+tmp+out) | "
            "HLO GFLOPs/dev | coll MiB/dev | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(res):
        r = res[key]
        if r["status"] == "ok":
            m = r["memory"]
            per_dev = (m["argument_size_in_bytes"] + m["temp_size_in_bytes"]
                       + m["output_size_in_bytes"]
                       - m.get("alias_size_in_bytes", 0))
            coll = sum(r.get("collective_bytes", {}).values())
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{per_dev/2**30:.2f} | {r['flops']/1e9:.0f} | "
                f"{coll/2**20:.0f} | {r.get('compile_s','')} |")
        elif r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skip | — | — | — | — |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | — | — | — | — |")
    return "\n".join(rows)


def roofline_table(tagged: bool = False) -> str:
    res = json.loads((RESULTS / "roofline.json").read_text())
    rows = ["| arch | shape | variant | compute ms | memory ms | coll ms | "
            "bound | MODEL/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(res):
        r = res[key]
        parts = key.split("|")
        tag = parts[3] if len(parts) > 3 else "baseline"
        if tagged != (tag != "baseline"):
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"skip | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {tag} | — | — | — |"
                        f" ERROR | — | — |")
            continue
        t = r["terms"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {tag} | "
            f"{t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} | "
            f"{t['collective_s']*1e3:.2f} | {r['dominant'].split('_')[0]} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def routing_table() -> str:
    """Policy comparison + per-node AftNode.stats() gauges from figr."""
    res = json.loads((RESULTS / "fig_routing.json").read_text())
    rr = next(p for p in res["policies"] if p["policy"] == "round_robin")
    rows = ["| policy | steps/s | vs round-robin | cluster hit rate | "
            "load imbalance |",
            "|---|---|---|---|---|"]
    for p in res["policies"]:
        speedup = p["steps_per_s"] / max(rr["steps_per_s"], 1e-9)
        rows.append(
            f"| {p['policy']} | {p['steps_per_s']:.0f} | {speedup:.2f}× | "
            f"{p['cluster_cache_hit_rate']:.3f} | {p['load_imbalance']:.2f} |")
    rows.append("")
    rows.append("| policy | node | commits | reads | cache hits | misses | "
                "hit rate |")
    rows.append("|---|---|---|---|---|---|---|")
    for p in res["policies"]:
        for n in p["nodes"]:
            rows.append(
                f"| {p['policy']} | {n['node']} | {n['commits']} | "
                f"{n['reads']} | {n['cache_hits']} | {n['cache_misses']} | "
                f"{n['cache_hit_rate']:.3f} |")
    kill = res["kill_midstream"]
    rows.append("")
    rows.append(
        f"kill-mid-stream ({kill['policy']}): {kill['completed']}/"
        f"{kill['workflows']} completed, {kill['workflows_retried']} retried "
        f"({kill['steps_memo_resumed']} memoized steps resumed), "
        f"standby promoted: {kill['standby_promoted']}, duplicates: "
        f"{kill['duplicate_effects']}, anomalies: {kill['anomalies']}")
    return "\n".join(rows)


def chain_table() -> str:
    """Kill-mid-handoff chaining audit (figc): AFT queue vs unscoped."""
    res = json.loads((RESULTS / "fig_chain.json").read_text())
    rows = ["| mode | chains×depth | handoff crashes | dropped triggers | "
            "duplicate effects | exactly-once |",
            "|---|---|---|---|---|---|"]
    for r in (res["aft"], res["baseline"]):
        ok = r["dropped_triggers"] == 0 and r["duplicate_effects"] == 0
        rows.append(
            f"| {r['mode']} | {r['chains']}×{r['depth']} | "
            f"{r['handoff_crashes']} | {r['dropped_triggers']} | "
            f"{r['duplicate_effects']} | {'yes' if ok else 'NO'} |")
    aft = res["aft"]
    rows.append("")
    rows.append(
        f"queue GC: {aft['queue_keys_before_gc']} q/ storage keys before "
        f"sweep → {aft['queue_keys_after_gc']} after (consumed entries ride "
        f"the w/ marker sweep)")
    return "\n".join(rows)


def io_table() -> str:
    """Async storage pipeline (figa): sync vs group commit + fault audit."""
    res = json.loads((RESULTS / "fig_async.json").read_text())
    rows = ["| commit path | steps/s | speedup | commit p50 ms | p99 ms |",
            "|---|---|---|---|---|"]
    for t in res["throughput"]:
        for mode in ("sync", "pipelined"):
            r = t[mode]
            speedup = (t["speedup_steps_per_s"]
                       if mode == "pipelined" else 1.0)
            rows.append(
                f"| {r['mode']} ({t['concurrent_workflows']} wf) | "
                f"{r['steps_per_s']:.0f} | {speedup:.2f}× | "
                f"{r['commit_p50_ms']:.2f} | {r['commit_p99_ms']:.1f} |")
    rows.append("")
    rows.append("| pipeline gauge | value |")
    rows.append("|---|---|")
    pl = res["throughput"][-1]["pipelined"]["pipeline"]
    for label, key in (("coalesce ratio (txns/flush)", "coalesce_ratio"),
                       ("mean flush items", "mean_flush_items"),
                       ("max flush items", "flush_size_max"),
                       ("flushes", "flushes"),
                       ("queue depth max", "depth_max"),
                       ("mean queue wait ms", "mean_queue_wait_ms")):
        rows.append(f"| {label} | {pl[key]} |")
    k = res["kill_mid_flush"]
    rows.append("")
    rows.append(
        f"kill-mid-flush: {sum(k['injected_kills'].values())} injected "
        f"({k['injected_kills']['flush']} pre-land, "
        f"{k['injected_kills']['flush_landed']} post-land, "
        f"{k['injected_kills'].get('delete_flush', 0)} gc-delete), "
        f"{k['workflow_retries']} retries → dropped {k['dropped_workflows']}, "
        f"duplicates {k['duplicate_commits']}, ordering violations "
        f"{k['ordering_violations']}, anomalies {k['anomalies']} — "
        f"exactly-once: {'yes' if k['exactly_once'] else 'NO'}")
    return "\n".join(rows)


def obs_table() -> str:
    """Per-node + cluster-merged registry snapshots with the commit-phase
    latency breakdown (obs layer; written by figw with tracing enabled)."""
    res = json.loads((RESULTS / "obs_metrics.json").read_text())

    def hist(snap: dict, key: str) -> tuple:
        h = snap.get(key)
        if not isinstance(h, dict) or not h.get("count"):
            return "—", "—"
        return f"{h['p50_ms']:.2f}", f"{h['p99_ms']:.2f}"

    rows = ["| scope | commits | commit p50/p99 ms | version flush p50 | "
            "probe p50 | record write p50 | queue wait p50 |",
            "|---|---|---|---|---|---|---|"]
    scopes = [(f"node {nid}", snap)
              for nid, snap in sorted(res["nodes"].items())]
    scopes.append(("cluster (merged)", res["cluster"]))
    for label, snap in scopes:
        p50, p99 = hist(snap, "commit.total")
        rows.append(
            f"| {label} | {snap.get('commits', 0)} | {p50}/{p99} | "
            f"{hist(snap, 'commit.version_flush')[0]} | "
            f"{hist(snap, 'commit.probe')[0]} | "
            f"{hist(snap, 'commit.record_write')[0]} | "
            f"{hist(snap, 'pipeline.queue_wait')[0]} |")
    trace = res.get("trace")
    if trace:
        rows.append("")
        rows.append(
            f"trace: {trace['events']} events, checker violations: "
            f"{trace['violations']} "
            f"({'clean' if not trace['violations'] else 'VIOLATIONS'})")
    return "\n".join(rows)


# section name → (title, renderer, `--only` hint when its results file is
# missing; None = results ship with the repo, let the error surface)
SECTIONS = {
    "dryrun": ("Dry-run matrix", dryrun_table, None),
    "roofline": ("Roofline baselines (single pod, 256 chips)",
                 lambda: roofline_table(tagged=False), None),
    "variants": ("Perf-iteration variants",
                 lambda: roofline_table(tagged=True), None),
    "routing": ("Routing policies (figr: 4 nodes, Zipf entities)",
                routing_table, "figr"),
    "chain": ("Cross-workflow chaining (figc: kill-mid-handoff)",
              chain_table, "figc"),
    "io": ("Async storage I/O pipeline (figa: group commit)",
           io_table, "figa"),
    "obs": ("Observability (per-node + gossip-merged registry, figw)",
            obs_table, "figw"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    if args.section != "all" and args.section not in SECTIONS:
        ap.error(
            f"unknown section {args.section!r}; registered sections: "
            f"all, {', '.join(SECTIONS)}"
        )
    for name, (title, render, hint) in SECTIONS.items():
        if args.section not in ("all", name):
            continue
        try:
            table = render()
        except FileNotFoundError:
            if hint is None:
                raise
            table = f"(run `python -m benchmarks.run --only {hint}` first)"
        print(f"### {title}\n")
        print(table)
        print()


if __name__ == "__main__":
    main()
