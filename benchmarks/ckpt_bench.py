"""Beyond-paper benchmark: AFT as the checkpoint fabric of the training
framework — save/restore throughput vs model size and chunking, plus the
torn-weight-refresh anomaly count with and without AFT (the serving-side
Table-2 analogue)."""

from __future__ import annotations

import threading
import time
from typing import Dict

import jax
import numpy as np

from repro.checkpoint import AftCheckpointer
from repro.checkpoint.serializer import leaf_to_bytes

from .common import QUICK_TIME_SCALE, engine, make_cluster, save


def _tree(n_leaves: int, leaf_kb: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = leaf_kb * 256  # f32 elements per leaf
    return {f"layer{i:03d}": rng.standard_normal(n).astype(np.float32)
            for i in range(n_leaves)}


def run(quick: bool = True) -> Dict:
    ts = QUICK_TIME_SCALE
    out: Dict[str, Dict] = {}

    # --- save/restore throughput vs size and chunking ----------------------
    for n_leaves, leaf_kb, chunk_kb in ((16, 64, 256), (64, 64, 256),
                                        (64, 256, 256), (64, 256, 1024)):
        cluster = make_cluster(engine("dynamodb", ts), time_scale=ts)
        ck = AftCheckpointer(cluster.client(), run_id="bench",
                             chunk_bytes=chunk_kb * 1024, writers=16)
        tree = _tree(n_leaves, leaf_kb)
        total_mb = n_leaves * leaf_kb / 1024
        t0 = time.perf_counter()
        res = ck.save(1, tree)
        save_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, restored, _ = ck.restore(like=tree)
        restore_s = time.perf_counter() - t0
        out[f"leaves{n_leaves}_leaf{leaf_kb}kb_chunk{chunk_kb}kb"] = {
            "total_mb": round(total_mb, 1),
            "keys": res.num_keys,
            "save_s": round(save_s, 3),
            "restore_s": round(restore_s, 3),
            "save_mb_s": round(total_mb / save_s, 1),
            "restore_mb_s": round(total_mb / restore_s, 1),
        }
        cluster.stop()

    # --- torn weight refresh: plain storage vs AFT --------------------------
    # a "trainer" rewrites all N leaves with a per-version tag while a
    # "server" repeatedly reads all leaves and checks version consistency.
    n_leaves, rounds, reads = 12, 30 if quick else 200, 60 if quick else 400

    def torn_reads_plain() -> int:
        eng = engine("dynamodb", ts)
        stop = threading.Event()
        torn = [0]

        def writer():
            v = 0
            while not stop.is_set():
                v += 1
                for i in range(n_leaves):
                    eng.put(f"w/{i}", f"{v}".encode())
                if v >= rounds:
                    break

        def reader():
            for _ in range(reads):
                versions = {eng.get(f"w/{i}") for i in range(n_leaves)}
                versions.discard(None)
                if len(versions) > 1:
                    torn[0] += 1

        wt = threading.Thread(target=writer)
        rt = threading.Thread(target=reader)
        wt.start(); rt.start()
        rt.join(); stop.set(); wt.join()
        return torn[0]

    def torn_reads_aft() -> int:
        cluster = make_cluster(engine("dynamodb", ts), time_scale=ts)
        client = cluster.client()
        stop = threading.Event()
        torn = [0]

        def writer():
            for v in range(1, rounds + 1):
                txid = client.start_transaction()
                for i in range(n_leaves):
                    client.put(txid, f"w/{i}", f"{v}".encode())
                client.commit_transaction(txid)
                if stop.is_set():
                    break

        def reader():
            for _ in range(reads):
                txid = client.start_transaction()
                try:
                    versions = {client.get(txid, f"w/{i}")
                                for i in range(n_leaves)}
                except Exception:
                    continue
                finally:
                    client.abort_transaction(txid)
                versions.discard(None)
                if len(versions) > 1:
                    torn[0] += 1

        wt = threading.Thread(target=writer)
        rt = threading.Thread(target=reader)
        wt.start(); rt.start()
        rt.join(); stop.set(); wt.join(timeout=30)
        cluster.stop()
        return torn[0]

    out["torn_weight_refresh"] = {
        "plain_torn_reads": torn_reads_plain(),
        "aft_torn_reads": torn_reads_aft(),
        "reader_samples": reads,
        "leaves": n_leaves,
    }
    save("ckpt_bench", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
