"""fig_workflow: DAG-composed requests — AFT-scoped vs. unscoped execution.

A fan-out-8/fan-in workflow (every branch read-modify-writes its own key,
the fan-in summarizes all branches) runs as a closed-loop stream under an
injected mid-branch crash rate ≥ 5%, in two modes:

* **aft** — the whole DAG is one AFT transaction (``TxnScope.WORKFLOW``)
  with memoized per-step resume; crashes retry the workflow under the same
  UUID and commit exactly once.
* **unscoped** — the baseline without the shim: branches write in place,
  immediately visible, with §6.1.2 metadata embedded; a crash leaves a
  fractured prefix and a retry re-applies effects.

A concurrent **auditor** plays the Table-2 role for DAGs: each audit reads
the summary plus every branch key as one observation and scores it with the
Definition-1 checker.  Exactly-once is scored at the end: every branch
counter must equal the number of completed workflows (each workflow
increments each branch exactly once, no matter how many attempts it took).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List

from repro.core import AftNode, AftNodeConfig, TransactionObserver
from repro.core.errors import ReadAbortError
from repro.core.records import extract_metadata
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.obs import trace as obs_trace
from repro.obs.checker import check_events
from repro.workflow import (
    TxnScope,
    WorkflowConfig,
    WorkflowError,
    WorkflowExecutor,
    WorkflowSpec,
)

from .common import QUICK_TIME_SCALE, engine, make_cluster, save

BRANCHES = 8
FAILURE_RATE = 0.08          # ≥ 5% per failure point, two points per branch


def branch_keys() -> List[str]:
    return [f"wf/branch{i}" for i in range(BRANCHES)]


def build_spec(epoch: int) -> WorkflowSpec:
    spec = WorkflowSpec(f"fanout{BRANCHES}")

    def branch_fn(ctx) -> int:
        key = f"wf/branch{ctx.branch}"
        raw = ctx.get(key)
        count = json.loads(raw)["count"] if raw else 0
        ctx.maybe_fail()  # the mid-branch fractional-execution hazard
        ctx.put(key, json.dumps({"count": count + 1, "epoch": epoch}).encode())
        return count + 1

    names = spec.fan_out("branch", branch_fn, BRANCHES)

    def summarize(ctx) -> int:
        counts = [ctx.inputs[n] for n in names]
        ctx.maybe_fail()
        ctx.put(
            "wf/summary",
            json.dumps({"epoch": epoch, "counts": counts}).encode(),
        )
        return sum(counts)

    spec.fan_in("summary", summarize, names, allow_skipped_deps=False)
    return spec


class Auditor:
    """Reads summary + all branch keys as ONE observation, repeatedly,
    concurrent with the workflow stream; scores with Definition 1."""

    def __init__(self, mode: str, *, cluster=None, storage=None):
        self.mode = mode
        self.cluster = cluster
        self.storage = storage
        self.audits = 0
        self.fr_anomalies = 0
        self.read_aborts = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def _audit_aft(self) -> None:
        node = self.cluster.pick_node()
        obs = TransactionObserver()
        tx = node.start_transaction()
        try:
            for key in ["wf/summary"] + branch_keys():
                value, tid = node.get_versioned(tx, key)
                cowritten = ()
                if tid is not None:
                    record = node.cache.get(tid)
                    if record is not None:
                        cowritten = record.write_set
                obs.observe_read(key, value, tid, cowritten)
        finally:
            node.abort_transaction(tx)
            node.release_transaction(tx)
        self.fr_anomalies += obs.fr_anomalies

    def _audit_plain(self) -> None:
        obs = TransactionObserver()
        for key in ["wf/summary"] + branch_keys():
            raw = self.storage.get(key)
            if raw is None:
                obs.observe_read(key, None, None)
                continue
            value, tid, cowritten = extract_metadata(raw)
            obs.observe_read(key, value, tid, cowritten)
        self.fr_anomalies += obs.fr_anomalies

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                if self.mode == "aft":
                    self._audit_aft()
                else:
                    self._audit_plain()
                self.audits += 1
            except ReadAbortError:
                self.read_aborts += 1  # §3.6 staleness abort, not an anomaly
            except Exception:
                pass  # cluster mid-teardown
            time.sleep(0.001)

    def start(self) -> None:
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5)


def _final_counts(storage) -> Dict[str, int]:
    """Read committed branch counters from the durable source of truth: a
    fresh node bootstrapped from the Commit Set (so no multicast races)."""
    node = AftNode(storage, AftNodeConfig(node_id="final-audit"))
    counts: Dict[str, int] = {}
    tx = node.start_transaction()
    for key in branch_keys():
        raw = node.get(tx, key)
        counts[key] = json.loads(raw)["count"] if raw else 0
    node.abort_transaction(tx)
    return counts


def _final_counts_plain(storage) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for key in branch_keys():
        raw = storage.get(key)
        if raw is None:
            counts[key] = 0
        else:
            value, _, _ = extract_metadata(raw)
            counts[key] = json.loads(value)["count"]
    return counts


def _run_mode(mode: str, workflows: int, ts: float, seed: int) -> Dict:
    store = engine("dynamodb", ts, seed=seed)
    platform = LambdaPlatform(
        FaasConfig(time_scale=ts, failure_rate=FAILURE_RATE,
                   max_workers=32, seed=seed)
    )
    cluster = None
    if mode == "aft":
        # one node: the workflow stream is a chain of read-modify-writes, and
        # AFT guarantees read atomicity, not serializability — cross-node
        # commit visibility is only eventual (multicast, §4), so the counter
        # chain pins to a single node exactly as §3.1 pins a transaction
        cluster = make_cluster(store, nodes=1, time_scale=ts)
        executor = WorkflowExecutor(
            platform, cluster=cluster,
            config=WorkflowConfig(scope=TxnScope.WORKFLOW, max_attempts=25),
        )
    else:
        executor = WorkflowExecutor(
            platform, storage=store,
            config=WorkflowConfig(
                scope=TxnScope.NONE, max_attempts=25,
                declared_writes=tuple(branch_keys()) + ("wf/summary",),
            ),
        )
    auditor = Auditor(mode, cluster=cluster, storage=store)
    auditor.start()

    completed = 0
    attempts = 0
    failed = 0
    t0 = time.perf_counter()
    for epoch in range(workflows):
        try:
            result = executor.run(build_spec(epoch))
            completed += 1
            attempts += result.attempts
        except WorkflowError:
            failed += 1
    wall = time.perf_counter() - t0
    auditor.stop()

    counts = _final_counts(store) if mode == "aft" else _final_counts_plain(store)
    # exactly-once: each completed workflow increments each branch once
    violations = sum(abs(c - completed) for c in counts.values())

    out = {
        "mode": mode,
        "workflows_completed": completed,
        "workflows_failed": failed,
        "attempts": attempts,
        "workflow_retries": executor.stats["workflow_retries"],
        "steps_memoized": executor.stats["steps_memoized"],
        "failures_injected": platform.failures_injected,
        "wall_s": round(wall, 2),
        "workflows_per_s": round(completed / wall, 2) if wall > 0 else 0.0,
        "audits": auditor.audits,
        "audit_read_aborts": auditor.read_aborts,
        "fr_anomalies": auditor.fr_anomalies,
        "exactly_once_violations": violations,
        "branch_counts": counts,
    }
    if cluster is not None:
        # cluster-merged metrics view: gossip the per-node registry
        # snapshots through the ICI plane when jax has devices, else take
        # the fault manager's direct in-process path — same merged view
        fm = cluster.fault_manager
        try:
            from repro.core.gossip import MetricsPlane

            MetricsPlane(cluster.live_nodes(), store, fault_manager=fm).step()
        except Exception:
            fm.collect_metrics()
        out["obs"] = fm.cluster_metrics()
    platform.shutdown()
    if cluster is not None:
        cluster.stop()
    return out


def run(quick: bool = True) -> Dict:
    ts = QUICK_TIME_SCALE
    workflows = 30 if quick else 120
    # tracing on for the aft stream: REPRO_TRACE_FILE adds the file sink
    # (the CI obs-check hook replays it); otherwise the ring buffer alone
    # feeds the offline checker below
    prev_tracer = obs_trace.get_tracer()
    tracer = obs_trace.enable(
        path=os.environ.get(obs_trace.TRACE_FILE_ENV), capacity=500_000
    )
    try:
        aft = _run_mode("aft", workflows, ts, seed=11)
    finally:
        obs_trace.set_tracer(prev_tracer)
        tracer.close()
    unscoped = _run_mode("unscoped", workflows, ts, seed=11)

    checked = check_events(tracer.events())
    aft["trace_events"] = len(tracer.events())
    aft["trace_violations"] = len(checked.violations)
    save("obs_metrics", {
        **aft.pop("obs", {"nodes": {}, "cluster": {}}),
        "trace": {"events": aft["trace_events"],
                  "violations": aft["trace_violations"],
                  "summary": checked.summary()},
    })
    out = {
        "branches": BRANCHES,
        "failure_rate": FAILURE_RATE,
        "workflows": workflows,
        "aft": aft,
        "unscoped": unscoped,
        "headline": {
            "aft_anomalies": aft["fr_anomalies"] + aft["exactly_once_violations"],
            "unscoped_anomalies": unscoped["fr_anomalies"]
            + unscoped["exactly_once_violations"],
            "aft_exactly_once": aft["exactly_once_violations"] == 0,
            "trace_violations": aft["trace_violations"],
        },
    }
    save("fig_workflow", out)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
