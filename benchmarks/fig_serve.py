"""fig_serve: continuous-batching inference serving on the AFT lane (figs).

Two claims about the serving stack (``serve/engine.py`` + ``serve/lane.py``):

1. **throughput** — on a mixed-length trace, the continuous-batching engine
   (fixed slots, chunked prefill interleaved with decode, join/leave
   mid-flight) beats static length-bucketed batching on tokens/sec and p99
   request latency.  The static baseline pays head-of-line blocking twice:
   every request in a bucket decodes until the bucket's *longest* request
   finishes, and a bucket must drain completely before the next is
   admitted.  The continuous engine retires each request the moment it
   finishes and backfills the slot from the queue — and compiles exactly
   one prefill/decode pair (shape-stable state), where the static path
   compiles one prefill per distinct (batch, prompt-length) shape.

2. **fault-tolerant serving lane** — requests expressed as read-only AFT
   workflows over a multi-node cluster keep serving through an atomic
   weight publish *and* a node hard-kill: session placement pins requests
   to per-node replicas, the refresher swaps weights read-atomically (zero
   torn weight sets, by construction and by audit), killed-node requests
   re-drive onto a live replica, and the offline checker replays the trace
   — including the ``weight_refresh`` spans' publish-UUID correlation —
   with zero violations.

Both engine arms exclude compile time symmetrically: each engine warms
every jit shape it will see before the clock starts.  Tokens/sec counts
only *requested* tokens, so the static arm's padding decode work shows up
as lost throughput, exactly as it does in production.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Sequence

import numpy as np

from .common import make_cluster, save

# mixed-length trace: plenty of shape diversity for the static path to
# fragment over, bounded so its compile warm-up stays benchmark-friendly
PROMPT_LENS = (4, 8, 16, 32)
# generation lengths are heavy-tailed in real serving traces: most replies
# are short, a few run long — exactly what static bucketing pays for, since
# the whole bucket decodes to its longest member
MAX_NEWS = (2, 4, 8, 32)
SESSION_ZIPF = 1.1
LANE_TIME_SCALE = 0.15


class _Req:
    __slots__ = ("session", "prompt", "max_new")

    def __init__(self, session: str, prompt: List[int], max_new: int):
        self.session = session
        self.prompt = prompt
        self.max_new = max_new


def make_trace(n: int, *, sessions: int, seed: int) -> List[_Req]:
    """Zipf-session, mixed-length request trace."""
    from repro.faas.workload import ZipfSampler

    rng = np.random.default_rng(seed)
    sampler = ZipfSampler(sessions, SESSION_ZIPF, seed=seed)
    out = []
    for _ in range(n):
        plen = int(rng.choice(PROMPT_LENS))
        prompt = [int(t) for t in rng.integers(1, 250, size=plen)]
        out.append(_Req(f"s{sampler.sample()}", prompt,
                        int(rng.choice(MAX_NEWS))))
    return out


def _p99_ms(lat_s: Sequence[float]) -> float:
    return round(float(np.percentile(np.asarray(lat_s), 99)) * 1e3, 1)


# ---------------------------------------------------------------------------
# engine arms (single process, no cluster): static vs continuous
# ---------------------------------------------------------------------------

def run_static(model, params, trace: List[_Req], scfg) -> Dict:
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(model, None, scfg, params=params)
    by_len: Dict[int, List[_Req]] = {}
    for r in trace:
        by_len.setdefault(len(r.prompt), []).append(r)
    buckets: List[List[_Req]] = []
    for plen in sorted(by_len):
        rs = by_len[plen]
        for i in range(0, len(rs), scfg.max_batch):
            buckets.append(rs[i:i + scfg.max_batch])
    # warm every (batch, prompt-len) jit shape — compile excluded, as for
    # the continuous arm; the count itself is part of the result
    for plen, batch in sorted({(len(b[0].prompt), len(b)) for b in buckets}):
        eng.generate([[1] * plen] * batch, 1)

    t0 = time.perf_counter()
    latencies: List[float] = []
    requested = wasted = 0
    for bucket in buckets:
        horizon = max(r.max_new for r in bucket)
        eng.generate([r.prompt for r in bucket], horizon)
        done = time.perf_counter() - t0
        for r in bucket:  # closed batch: every request "arrived" at t0
            latencies.append(done)
            requested += r.max_new
            wasted += horizon - r.max_new
    wall = time.perf_counter() - t0
    return {
        "requests": len(trace),
        "buckets": len(buckets),
        "wall_s": round(wall, 3),
        "tokens_per_s": round(requested / wall, 1),
        "p99_ms": _p99_ms(latencies),
        "wasted_decode_tokens": wasted,  # padding to the bucket horizon
        "compiles": eng.compile_counts(),
    }


def run_continuous(model, params, trace: List[_Req], scfg) -> Dict:
    from repro.serve.engine import ContinuousEngine

    eng = ContinuousEngine(model, None, scfg, params=params)
    warm = eng.submit([1, 2, 3], 2)  # max_new=2: compiles prefill AND decode
    while not warm.done():
        eng.step()

    t0 = time.perf_counter()
    tickets = [eng.submit(r.prompt, r.max_new) for r in trace]
    while not all(t.done() for t in tickets):
        if not eng.step():
            time.sleep(0.001)  # nothing admissible this instant
    wall = time.perf_counter() - t0
    requested = sum(r.max_new for r in trace)
    latencies = [t.finished_at - t0 for t in tickets]
    counts = eng.compile_counts()
    assert counts["prefill"] <= 1 and counts["decode"] <= 1, counts
    return {
        "requests": len(trace),
        "wall_s": round(wall, 3),
        "tokens_per_s": round(requested / wall, 1),
        "p99_ms": _p99_ms(latencies),
        "decode_iters": eng.stats["decode_iters"],
        "prefill_chunks": eng.stats["prefill_chunks"],
        "compiles": counts,
    }


# ---------------------------------------------------------------------------
# the serving lane: multi-node, refresh under traffic, node kill
# ---------------------------------------------------------------------------

def run_lane(model, params, trace: List[_Req], scfg, *, nodes: int,
             seed: int) -> Dict:
    import jax

    from repro.obs import trace as obs_trace
    from repro.obs.checker import check_events
    from repro.faas.platform import FaasConfig, LambdaPlatform
    from repro.serve.lane import InferenceLane, LaneConfig
    from repro.serve.engine import ContinuousEngine
    from repro.storage.memory import MemoryStorage
    from repro.workflow import PoolConfig, TxnScope, WorkflowPool

    params2 = jax.tree.map(lambda x: x * 1.01, params)
    cluster = make_cluster(MemoryStorage(), nodes=nodes, standby=0,
                           time_scale=LANE_TIME_SCALE,
                           router="consistent_hash")
    platform = LambdaPlatform(
        FaasConfig(time_scale=0.0, max_workers=32, seed=seed))
    pool = WorkflowPool(
        platform, cluster=cluster,
        config=PoolConfig(scope=TxnScope.STEP, max_attempts=10))
    replicas = {n.node_id: ContinuousEngine(model, None, scfg,
                                            name=f"rep-{n.node_id}")
                for n in cluster.live_nodes()}
    lane = InferenceLane(pool, cluster, replicas,
                         config=LaneConfig(run_id="figserve",
                                           poll_every_s=0.05,
                                           request_timeout_s=120.0))

    prev_tracer = obs_trace.get_tracer()
    tracer = obs_trace.enable(
        path=os.environ.get(obs_trace.TRACE_FILE_ENV), capacity=500_000)
    results, errors = [], []
    try:
        lane.publish(params, 1)
        deadline = time.perf_counter() + 60
        while (any(e.weights_step < 1 for e in replicas.values())
               and time.perf_counter() < deadline):
            lane.poll_weights()
            time.sleep(0.01)
        assert all(e.weights_step == 1 for e in replicas.values())
        for eng in replicas.values():
            eng.start()
        # warm every replica's jit pair before the clock starts
        for eng in replicas.values():
            eng.submit([1, 2, 3], 2).result(timeout=120)
        lane.start_refresher()

        third = max(len(trace) // 3, 1)
        t0 = time.perf_counter()
        tickets = [lane.submit(r.session, r.prompt, max_new=r.max_new)
                   for r in trace]

        def _wait_done(n: int) -> None:
            deadline = time.perf_counter() + 120
            while (sum(t.done() for t in tickets) < n
                   and time.perf_counter() < deadline):
                time.sleep(0.002)

        # atomic weight publish once traffic is genuinely in flight, then
        # a hard node kill while the remaining requests stream
        _wait_done(third)
        lane.publish(params2, 2)
        _wait_done(2 * third)
        victim = cluster.live_nodes()[-1]
        cluster.kill_node(len(cluster.live_nodes()) - 1)
        lane.detach(victim.node_id)
        for t in tickets:
            try:
                results.append(InferenceLane.payload(t.result(timeout=300)))
            except Exception as exc:  # audit, don't mask
                errors.append(repr(exc))
        wall = time.perf_counter() - t0
    finally:
        lane.stop()
        obs_trace.set_tracer(prev_tracer)
        tracer.close()
        pool.close()
        platform.shutdown()
        cluster.stop()

    checked = check_events(tracer.events())
    requested = sum(r.max_new for r in trace)
    steps_served = sorted({r["weights_step"] for r in results})
    refresh_spans = sum(
        1 for ev in tracer.events()
        if ev.get("ev") == "span" and ev.get("name") == "weight_refresh")
    return {
        "nodes": nodes,
        "requests": len(trace),
        "sessions": len({r.session for r in trace}),
        "wall_s": round(wall, 3),
        "tokens_per_s": round(requested / wall, 1),
        "completed": len(results),
        "incomplete_requests": len(trace) - len(results),
        "errors": errors[:4],
        "weight_steps_served": steps_served,
        "served_both_steps": steps_served == [1, 2],
        "killed_node": victim.node_id,
        "rerouted": lane.stats["rerouted"],
        "torn_weight_reads": lane.stats["torn_reads"],
        "refresh_installs": lane.stats["refresh_installs"],
        "snapshot_skips": lane.stats["snapshot_skips"],
        "refresh_spans": refresh_spans,
        "trace_events": len(tracer.events()),
        "checker_violations": len(checked.violations),
        "checker_refreshes": checked.refreshes_checked,
    }


def run(quick: bool = True) -> Dict:
    import jax

    from repro.models import Model
    from repro.models.config import get_config
    from repro.serve.engine import ServeConfig

    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    if smoke:
        n, lane_n, sessions, nodes = 48, 24, 6, 2
    elif quick:
        n, lane_n, sessions, nodes = 96, 48, 10, 3
    else:
        n, lane_n, sessions, nodes = 192, 96, 24, 3

    cfg = get_config("tinyllama-1.1b").reduced(pattern_repeats=2)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    scfg = ServeConfig(max_batch=8, max_len=96, slots=8, prefill_chunk=16)

    trace = make_trace(n, sessions=sessions, seed=11)
    static = run_static(model, params, trace, scfg)
    continuous = run_continuous(model, params, trace, scfg)
    lane = run_lane(model, params,
                    make_trace(lane_n, sessions=sessions, seed=13),
                    scfg, nodes=nodes, seed=17)

    out = {
        "model": cfg.name,
        "requests": n,
        "prompt_lens": list(PROMPT_LENS),
        "max_new": list(MAX_NEWS),
        "static": static,
        "continuous": continuous,
        "lane": lane,
        "headline": {
            "speedup_tokens_per_s": round(
                continuous["tokens_per_s"]
                / max(static["tokens_per_s"], 1e-9), 2),
            "p99_ratio": round(
                static["p99_ms"] / max(continuous["p99_ms"], 1e-9), 2),
            "continuous_compiles": continuous["compiles"],
            "static_compiles": static["compiles"],
            "lane_torn_weight_reads": lane["torn_weight_reads"],
            "lane_checker_violations": lane["checker_violations"],
            "lane_incomplete_requests": lane["incomplete_requests"],
            "lane_served_both_steps": lane["served_both_steps"],
        },
    }
    save("fig_serve", out)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
