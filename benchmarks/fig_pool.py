"""fig_pool: batched workflow scheduling at scale (WorkflowPool vs. executor).

Two claims, both prerequisites for the paper's "thousands of requests per
second" (§6) when the requests are many small workflow DAGs:

1. **throughput** — a sweep over concurrent-workflow count compares
   per-workflow ``WorkflowExecutor.run()`` loops (each ready step pays its
   own platform invocation) against one shared ``WorkflowPool`` (ready steps
   from different workflows batched into single invocations).  The pool
   sustains ≥ 1000 concurrent workflows with higher steps/sec and an order
   of magnitude fewer platform invocations;

2. **bounded storage** — the same pool stream run in waves, with and without
   the finished-workflow GC sweep (``LocalGcAgent`` + fault-manager global
   GC): without GC the ``.wf/`` memo records and ``u/`` index entries grow
   monotonically with every workflow ever run; with GC the storage key count
   plateaus.

Each workflow is a 3-step DAG (fan-out-2 → fan-in) of small read-modify-write
steps — the "thousands of concurrent small workflows" shape from ROADMAP.
"""

from __future__ import annotations

import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List

from repro.core.gc import LocalGcAgent
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.obs import trace as obs_trace
from repro.workflow import (
    PoolConfig,
    TxnScope,
    WorkflowConfig,
    WorkflowExecutor,
    WorkflowPool,
    WorkflowSpec,
)

from .common import engine, make_cluster, save

STEPS_PER_WORKFLOW = 3
FAILURE_RATE = 0.02
# Bounded logical keyspace: a high-throughput service hits the same entities
# over and over, so old versions get superseded and the §5 GC can reclaim
# them.  Each workflow RMWs the entity group (wf % KEYSPACE).
KEYSPACE = 128
# The platform grants a fixed number of concurrent function slots (Lambda
# reserved-concurrency shape) and a warm start costs ~25 sim-ms.  Slots are
# the scarce resource the pool's batching economizes: an executor loop burns
# one warm start per step, the pool packs batch_max_steps steps per start.
FUNCTION_SLOTS = 8
WARM_LATENCY_MS = 25.0
# This figure runs less compressed than the rest of the suite: at the global
# QUICK_TIME_SCALE the simulated invoke/storage latencies shrink below the
# Python interpreter's own per-step cost, and the quantity under study
# (per-invocation overhead) disappears into CPU noise.
POOL_TIME_SCALE = 0.15


def build_spec(wf: int) -> WorkflowSpec:
    spec = WorkflowSpec(f"small-{wf}")
    entity = wf % KEYSPACE

    def shard(ctx):
        key = f"pool/{entity}/s{ctx.branch}"
        raw = ctx.get(key)
        count = int(raw) if raw else 0
        ctx.maybe_fail()
        ctx.put(key, str(count + 1).encode())
        return count + 1

    names = spec.fan_out("shard", shard, 2)

    def agg(ctx):
        total = sum(ctx.inputs[n] for n in names)
        ctx.put(f"pool/{entity}/sum", str(total).encode())
        return total

    spec.fan_in("agg", agg, names, allow_skipped_deps=False)
    return spec


def _platform(ts: float, seed: int) -> LambdaPlatform:
    return LambdaPlatform(
        FaasConfig(time_scale=ts, failure_rate=FAILURE_RATE,
                   warm_latency_ms=WARM_LATENCY_MS,
                   max_workers=FUNCTION_SLOTS, seed=seed)
    )


# ---------------------------------------------------------------------------
# throughput sweep: executor loop vs pool
# ---------------------------------------------------------------------------

def _run_executor_loop(n: int, ts: float, seed: int) -> Dict:
    """Baseline: n concurrent clients each driving WorkflowExecutor.run()
    (closed-loop, one invocation per step — the pre-pool shape)."""
    store = engine("dynamodb", ts, seed=seed)
    platform = _platform(ts, seed)
    cluster = make_cluster(store, nodes=1, time_scale=ts)
    ex = WorkflowExecutor(
        platform, cluster=cluster,
        config=WorkflowConfig(scope=TxnScope.WORKFLOW, max_attempts=25),
    )
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=32) as drivers:
        results = list(drivers.map(lambda i: ex.run(build_spec(i)), range(n)))
    wall = time.perf_counter() - t0
    steps = sum(r.steps_run for r in results)
    out = {
        "workflows": n,
        "wall_s": round(wall, 3),
        "steps_run": steps,
        "steps_per_s": round(steps / wall, 1),
        "workflows_per_s": round(n / wall, 1),
        "invocations": platform.invocations,
        "invocations_per_step": round(platform.invocations / steps, 3),
    }
    platform.shutdown()
    cluster.stop()
    return out


def _run_pool(n: int, ts: float, seed: int) -> Dict:
    store = engine("dynamodb", ts, seed=seed)
    platform = _platform(ts, seed)
    cluster = make_cluster(store, nodes=1, time_scale=ts)
    cfg = PoolConfig(
        scope=TxnScope.WORKFLOW, max_attempts=25,
        batch_max_steps=16, max_inflight_steps=256,
        max_admitted_workflows=4096,
    )
    t0 = time.perf_counter()
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [pool.submit(build_spec(i)) for i in range(n)]
        results = [t.result(timeout=600) for t in tickets]
    wall = time.perf_counter() - t0
    steps = sum(r.steps_run for r in results)
    out = {
        "workflows": n,
        "wall_s": round(wall, 3),
        "steps_run": steps,
        "steps_per_s": round(steps / wall, 1),
        "workflows_per_s": round(n / wall, 1),
        "invocations": platform.invocations,
        "invocations_per_step": round(platform.invocations / steps, 3),
        "batches": platform.batched_invocations,
        "mean_batch_size": round(
            platform.batched_steps / max(platform.batched_invocations, 1), 2
        ),
        "max_admitted": pool.stats["max_admitted"],
    }
    platform.shutdown()
    cluster.stop()
    return out


# ---------------------------------------------------------------------------
# storage footprint: memo-record GC on vs off
# ---------------------------------------------------------------------------

def _run_footprint(waves: int, per_wave: int, ts: float, seed: int,
                   gc: bool) -> Dict:
    store = engine("dynamodb", ts, seed=seed)
    platform = _platform(ts, seed)
    cluster = make_cluster(store, nodes=1, time_scale=ts)
    # single node: its agent sweeps immediately, so markers can retire at once
    cluster.fault_manager.config.workflow_marker_ttl_s = 0.0
    agent = LocalGcAgent(cluster.live_nodes()[0], workflow_gc_batch=100_000)
    cfg = PoolConfig(
        scope=TxnScope.WORKFLOW, max_attempts=25,
        batch_max_steps=16, max_inflight_steps=256,
        declare_finished=True,
    )
    sizes: List[int] = []
    memo_keys: List[int] = []
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        for wave in range(waves):
            base = wave * per_wave
            tickets = [
                pool.submit(build_spec(base + i)) for i in range(per_wave)
            ]
            for t in tickets:
                t.result(timeout=600)
            if gc:
                agent.step()
                cluster.fault_manager.step()
                cluster.fault_manager.deleter.drain()
            sizes.append(len(store.list_keys()))
            memo_keys.append(len(store.list_keys("d/.wf/")))
    platform.shutdown()
    cluster.stop()
    return {
        "gc": gc,
        "waves": waves,
        "workflows_per_wave": per_wave,
        "total_keys_per_wave": sizes,
        "memo_keys_per_wave": memo_keys,
        "final_keys": sizes[-1],
        "plateaued": sizes[-1] <= sizes[0] * 1.5 if gc else False,
    }


def run(quick: bool = True) -> Dict:
    ts = POOL_TIME_SCALE
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    if smoke:
        sweep = [50, 200]
        waves, per_wave = 3, 40
    elif quick:
        sweep = [100, 300, 1000]
        waves, per_wave = 4, 150
    else:
        sweep = [100, 300, 1000, 3000]
        waves, per_wave = 6, 400

    throughput = []
    for n in sweep:
        loop = _run_executor_loop(n, ts, seed=n)
        pool = _run_pool(n, ts, seed=n)
        throughput.append({
            "concurrent_workflows": n,
            "executor_loop": loop,
            "pool": pool,
            "speedup_steps_per_s": round(
                pool["steps_per_s"] / max(loop["steps_per_s"], 1e-9), 2
            ),
            "invocation_amortization": round(
                loop["invocations"] / max(pool["invocations"], 1), 2
            ),
        })

    no_gc = _run_footprint(waves, per_wave, ts, seed=1, gc=False)
    with_gc = _run_footprint(waves, per_wave, ts, seed=1, gc=True)

    # observability overhead: the largest arm re-run with span tracing on
    # (ring sink), against a fresh tracing-off baseline with the same seed.
    # The registry rides both arms (it is always on); this isolates the
    # optional part — per-step span emission + trace-id hashing.
    n = sweep[-1]
    base = _run_pool(n, ts, seed=n + 1)
    prev_tracer = obs_trace.get_tracer()
    tracer = obs_trace.enable(capacity=200_000)
    try:
        traced = _run_pool(n, ts, seed=n + 1)
    finally:
        obs_trace.set_tracer(prev_tracer)
    overhead_pct = round(
        (base["steps_per_s"] - traced["steps_per_s"])
        / max(base["steps_per_s"], 1e-9) * 100, 2
    )
    obs_overhead = {
        "concurrent_workflows": n,
        "steps_per_s_tracing_off": base["steps_per_s"],
        "steps_per_s_tracing_on": traced["steps_per_s"],
        "overhead_pct": overhead_pct,
        "trace_events": len(tracer.events()),
    }
    print(
        f"[fig_pool] obs overhead @ {n} workflows: "
        f"{base['steps_per_s']:.1f} steps/s tracing off vs "
        f"{traced['steps_per_s']:.1f} tracing on ({overhead_pct:+.2f}%)"
    )

    biggest = throughput[-1]
    out = {
        "steps_per_workflow": STEPS_PER_WORKFLOW,
        "failure_rate": FAILURE_RATE,
        "throughput_sweep": throughput,
        "footprint": {"no_gc": no_gc, "with_gc": with_gc},
        "obs_overhead": obs_overhead,
        "headline": {
            "max_concurrent_workflows": biggest["concurrent_workflows"],
            "pool_steps_per_s": biggest["pool"]["steps_per_s"],
            "executor_steps_per_s": biggest["executor_loop"]["steps_per_s"],
            "pool_faster": biggest["pool"]["steps_per_s"]
            > biggest["executor_loop"]["steps_per_s"],
            "mean_batch_size": biggest["pool"]["mean_batch_size"],
            "final_keys_no_gc": no_gc["final_keys"],
            "final_keys_with_gc": with_gc["final_keys"],
            "storage_plateaus_with_gc": with_gc["plateaued"],
            "obs_overhead_pct": obs_overhead["overhead_pct"],
        },
    }
    save("fig_pool", out)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
