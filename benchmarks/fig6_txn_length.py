"""Fig 6: transaction length — 1..10 functions (3 IOs each: 2 reads,
1 write), AFT over DynamoDB and Redis."""

from __future__ import annotations

from typing import Dict

from repro.faas.workload import run_workload

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg


def run(quick: bool = True) -> Dict:
    clients = 10
    per_client = 30 if quick else 1000
    ts = QUICK_TIME_SCALE
    out: Dict[str, Dict] = {}
    for nfuncs in (1, 2, 4, 6, 8, 10):
        row = {}
        for store in ("dynamodb", "redis"):
            cluster = make_cluster(engine(store, ts), time_scale=ts)
            cfg = workload_cfg(functions=nfuncs, reads=2, writes=1,
                               time_scale=ts, seed=nfuncs)
            res = run_workload("aft", cfg=cfg, clients=clients,
                               txns_per_client=per_client, cluster=cluster)
            row[f"aft_{store}"] = res.summary()
            cluster.stop()
        out[f"functions_{nfuncs}"] = row
    save("fig6_txn_length", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
