"""Benchmark driver: one module per paper figure/table + framework benches.

  PYTHONPATH=src python -m benchmarks.run            # quick mode (minutes)
  PYTHONPATH=src python -m benchmarks.run --only fig3,fig9
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale counts
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: seconds, tiny counts

Roofline/dry-run artifacts (benchmarks/results/{dryrun,roofline}.json) are
produced by ``repro.launch.dryrun`` / ``repro.launch.roofline`` — see
EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import importlib
import json
import time
import traceback

MODULES = {
    "fig2": "benchmarks.fig2_io_latency",
    "fig3": "benchmarks.fig3_table2_e2e",     # includes table2
    "fig4": "benchmarks.fig4_caching_skew",
    "fig5": "benchmarks.fig5_rw_ratio",
    "fig6": "benchmarks.fig6_txn_length",
    "fig7": "benchmarks.fig7_single_node",
    "fig8": "benchmarks.fig8_distributed",
    "fig9": "benchmarks.fig9_gc",
    "fig10": "benchmarks.fig10_fault_tolerance",
    "figw": "benchmarks.fig_workflow",
    "figp": "benchmarks.fig_pool",
    "figr": "benchmarks.fig_routing",
    "figc": "benchmarks.fig_chain",
    "figa": "benchmarks.fig_async",
    "fige": "benchmarks.fig_elastic",
    "figh": "benchmarks.fig_hotpath",
    "figs": "benchmarks.fig_serve",   # needs the [jax] extra
    "ckpt": "benchmarks.ckpt_bench",
}

# fast, representative subset for CI smoke runs (seconds each)
SMOKE_DEFAULT = ["fig2", "figw", "figp", "figr", "figc", "figa", "fige",
                 "figh"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig3,fig9")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale txn counts (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny counts, fast subset unless --only")
    args = ap.parse_args()
    if args.smoke:
        # modules that support it shrink their counts further than quick mode
        import os
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    names = [n.strip() for n in args.only.split(",") if n.strip()] \
        or (SMOKE_DEFAULT if args.smoke else list(MODULES))
    failures = 0
    for name in names:
        mod = importlib.import_module(MODULES[name])
        t0 = time.time()
        print(f"=== {name} ({MODULES[name]}) ===", flush=True)
        try:
            result = mod.run(quick=not args.full)
            dt = time.time() - t0
            summary = json.dumps(result, indent=1, default=str)
            if len(summary) > 1800:
                summary = summary[:1800] + "\n ...(see benchmarks/results)"
            print(summary)
            print(f"=== {name} done in {dt:.1f}s ===", flush=True)
        except Exception:
            failures += 1
            print(f"=== {name} FAILED ===")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
