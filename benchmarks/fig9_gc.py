"""Fig 9: garbage-collection overhead — one node, 40 clients, Zipf 1.5;
throughput with global GC enabled vs disabled, plus deletion rate and
storage-footprint effect."""

from __future__ import annotations

from typing import Dict

from repro.faas.workload import run_workload

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg


def run(quick: bool = True) -> Dict:
    clients = 40
    per_client = 25 if quick else 250
    ts = QUICK_TIME_SCALE
    out: Dict[str, Dict] = {}
    for gc_on in (True, False):
        eng = engine("dynamodb", ts)
        cluster = make_cluster(eng, time_scale=ts,
                               gc_interval_s=0.05 if gc_on else 1e9)
        if not gc_on:
            cluster.fault_manager.config.gc_interval_s = 1e9
        else:
            cluster.fault_manager.config.gc_interval_s = 0.05
        cfg = workload_cfg(zipf=1.5, time_scale=ts, seed=7)
        res = run_workload("aft", cfg=cfg, clients=clients,
                           txns_per_client=per_client, cluster=cluster)
        # settle: let local GC mark supersedence and the global GC collect
        if gc_on:
            import time as _t

            for _ in range(8):
                for agent in cluster.gc_agents.values():
                    agent.step()
                cluster.fault_manager.step()
                _t.sleep(0.02)
        s = res.summary()
        s["deleted_txns"] = cluster.fault_manager.stats.get(
            "gc_deleted_txns", 0)
        s["commit_records_left"] = len(
            eng.list_keys("c/")) if hasattr(eng, "list_keys") else -1
        s["data_keys_left"] = len(
            eng.list_keys("d/")) if hasattr(eng, "list_keys") else -1
        out["gc_enabled" if gc_on else "gc_disabled"] = s
        cluster.stop()
    on, off = out["gc_enabled"], out["gc_disabled"]
    out["throughput_delta_pct"] = round(
        100.0 * (on["tps"] - off["tps"]) / max(off["tps"], 1e-9), 2)
    save("fig9_gc", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
