"""fig_routing: placement-aware routing across AFT nodes (figr).

Two claims about the routing layer (``core/routing.py``):

1. **locality** — on a multi-node cluster serving a skewed workflow stream,
   locality-aware placement (``consistent_hash``, ``cache_aware``) beats the
   paper's stateless round-robin LB on both steps/sec and node data-cache
   hit rate.  The workload is entity-shaped (Cloudburst's observation): each
   workflow reads every key of ONE entity group, entities drawn Zipf(1.1).
   Round-robin makes all four node caches fight over the same global hot
   set — and thrash, because one cache is far smaller than the working
   set — while hash placement partitions entities so the cluster's caches
   add up, and cache-aware scoring additionally spills a hot entity off its
   overloaded ring owner onto neighbours (which then cache it too);

2. **fault-tolerant rerouting** — a node hard-killed mid-stream is routed
   around (ring resync on the fault-manager callback), every affected
   workflow retries onto a live node with memoized resume, the standby is
   promoted, and a post-replacement wave routes over the healed ring: all
   workflows complete, every RMW counter lands exactly once, and the
   atomically co-written mirror key never diverges (zero anomalies, zero
   duplicate effects).

Methodology notes: the throughput phase disables per-step memo commits so
the measured quantity is the read path (memo writes are identical across
policies and would only add constant noise); each policy runs on a fresh
engine + cluster with identical seeds, so caches start cold everywhere.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

from repro.core import AftNode, AftNodeConfig
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.faas.workload import ZipfSampler
from repro.workflow import PoolConfig, TxnScope, WorkflowPool, WorkflowSpec

from .common import engine, make_cluster, save

NODES = 4
ZIPF_THETA = 1.1
ENTITIES = 64              # entity groups, drawn Zipf(theta) per workflow
KEYS_PER_ENTITY = 10       # a workflow reads ALL keys of its entity
VALUE_BYTES = 4096
CACHE_KEYS_PER_NODE = 64   # per-node data cache ≪ working set ⇒ placement
                           # decides whether caches overlap or add up
# The throughput phase runs much less compressed than the rest of the
# suite: the quantity under study (a cache hit saving a storage read) only
# shows when the storage read costs more than the scheduler's own per-step
# Python overhead.  Few platform slots for the same reason — the stream
# must be storage-bound, not scheduler-bound.
THROUGHPUT_TIME_SCALE = 1.6
THROUGHPUT_WORKERS = 6
# The kill phase studies rerouting, not latency: the fast scale keeps the
# §6.7 replacement delay (scaled by time_scale in common.make_cluster)
# within CI budgets.
KILL_TIME_SCALE = 0.15
POLICIES = ("round_robin", "consistent_hash", "cache_aware")


def entity_keys(ent: int) -> Tuple[str, ...]:
    return tuple(f"e/{ent}/k{j}" for j in range(KEYS_PER_ENTITY))


def read_spec(wf: int, ent: int) -> WorkflowSpec:
    """fetch (reads the whole entity group) → emit (summarize; a serving-
    shaped stream is read-mostly, so only every 8th workflow persists its
    output — the rest commit read-only)."""
    spec = WorkflowSpec(f"route-{wf}")
    keys = entity_keys(ent)

    def fetch(ctx):
        total = 0
        for key in keys:
            raw = ctx.get(key)
            total += len(raw) if raw else 0
        return total

    def emit(ctx):
        if wf % 8 == 0:
            ctx.put(f"out/{wf}", str(ctx.inputs["fetch"]).encode())
        return ctx.inputs["fetch"]

    spec.step("fetch", fetch, reads=keys)
    spec.step("emit", emit, deps=["fetch"])
    return spec


def counter_spec(wf: int) -> WorkflowSpec:
    """RMW a private counter AND an atomically co-written mirror — the
    exactly-once + fractured-state probe for the kill phase."""
    spec = WorkflowSpec(f"cnt-{wf}")

    def bump(ctx):
        raw = ctx.get(f"cnt/{wf}")
        count = json.loads(raw)["count"] if raw else 0
        ctx.maybe_fail()
        payload = json.dumps({"count": count + 1}).encode()
        ctx.put(f"cnt/{wf}", payload)
        ctx.put(f"cnt2/{wf}", payload)  # must never diverge from cnt/
        return count + 1

    spec.step("bump", bump, reads=(f"cnt/{wf}",))
    return spec


def _prepopulate(cluster) -> None:
    node = cluster.live_nodes()[0]
    tx = node.start_transaction()
    for ent in range(ENTITIES):
        for key in entity_keys(ent):
            node.put(tx, key, b"v" * VALUE_BYTES)
    node.commit_transaction(tx)
    node.release_transaction(tx)
    cluster.step_all()  # multicast the commit metadata to every node


def _node_report(cluster) -> List[Dict]:
    rows = []
    for node in cluster.live_nodes():
        snap = node.stats()
        rows.append({
            "node": node.node_id,
            "commits": snap["commits"],
            "reads": snap["reads"],
            "cache_hits": snap["data_cache_hits"],
            "cache_misses": snap["data_cache_misses"],
            "cache_hit_rate": round(snap["data_cache_hit_rate"], 3),
        })
    return rows


def _run_policy(policy: str, workflows: int, ts: float, seed: int) -> Dict:
    store = engine("dynamodb", ts, seed=seed)
    cluster = make_cluster(
        store, nodes=NODES, time_scale=ts, router=policy,
        data_cache_bytes=CACHE_KEYS_PER_NODE * VALUE_BYTES,
    )
    _prepopulate(cluster)
    platform = LambdaPlatform(
        FaasConfig(time_scale=ts, max_workers=THROUGHPUT_WORKERS, seed=seed)
    )
    sampler = ZipfSampler(ENTITIES, ZIPF_THETA, seed=seed)
    specs = [read_spec(i, sampler.sample()) for i in range(workflows)]
    cfg = PoolConfig(
        scope=TxnScope.WORKFLOW, memoize=False,
        # static batch size: adaptive sizing reacts to each policy's own
        # step latencies, which would confound the placement comparison —
        # scheduling is held identical so placement is the only variable
        batch_max_steps=8,
        max_inflight_steps=64,
        # closed-loop admission: a bounded window of open sessions is the
        # realistic serving shape AND what makes the cache-aware policy's
        # open-session load signal proportional to actual concurrency
        max_admitted_workflows=64,
    )
    t0 = time.perf_counter()
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [pool.submit(s) for s in specs]
        results = [t.result(timeout=600) for t in tickets]
    wall = time.perf_counter() - t0
    steps = sum(r.steps_run for r in results)
    nodes = _node_report(cluster)
    hits = sum(n["cache_hits"] for n in nodes)
    misses = sum(n["cache_misses"] for n in nodes)
    commits = [n["commits"] for n in nodes]
    out = {
        "policy": policy,
        "workflows": workflows,
        "wall_s": round(wall, 3),
        "steps_per_s": round(steps / wall, 1),
        "cluster_cache_hit_rate": round(hits / max(hits + misses, 1), 3),
        "load_imbalance": round(max(commits) / max(min(commits), 1), 2),
        "nodes": nodes,
        "batch_target": pool.stats["batch_target"],
    }
    platform.shutdown()
    cluster.stop()
    return out


def _run_kill_midstream(workflows: int, ts: float, seed: int) -> Dict:
    """Kill a node while a hinted stream is in flight; prove rerouting +
    standby replacement keep exactly-once (counters == 1) and atomicity
    (the co-written mirror never diverges)."""
    store = engine("dynamodb", ts, seed=seed)
    cluster = make_cluster(
        store, nodes=NODES, time_scale=ts, standby=1, fast_failover=True,
        router="consistent_hash",
        data_cache_bytes=CACHE_KEYS_PER_NODE * VALUE_BYTES,
    )
    platform = LambdaPlatform(
        FaasConfig(time_scale=ts, max_workers=32, seed=seed)
    )
    cfg = PoolConfig(
        scope=TxnScope.WORKFLOW, max_attempts=25,
        max_inflight_steps=256, max_admitted_workflows=8192,
    )
    wave2 = max(workflows // 4, 8)
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [pool.submit(counter_spec(i)) for i in range(workflows)]
        # let the stream get going, then hard-kill a node mid-flight
        deadline = time.perf_counter() + 30
        while (
            sum(t.done() for t in tickets) < workflows // 3
            and time.perf_counter() < deadline
        ):
            time.sleep(0.005)
        killed_id = cluster.kill_node(1).node_id
        results = [t.result(timeout=600) for t in tickets]
        retried = sum(1 for r in results if r.attempts > 1)
        memo_resumes = sum(r.steps_memoized for r in results)
        # §6.7 end-to-end: wait for the fault manager to promote the standby
        deadline = time.perf_counter() + 30
        while (
            len(cluster.live_nodes()) < NODES
            and time.perf_counter() < deadline
        ):
            time.sleep(0.02)
        replaced = len(cluster.live_nodes())
        # post-replacement wave: the healed ring (replacement included)
        # serves new traffic with the same guarantees
        wave2_tickets = [
            pool.submit(counter_spec(workflows + i)) for i in range(wave2)
        ]
        wave2_results = [t.result(timeout=600) for t in wave2_tickets]

    total = workflows + wave2
    # audit from the durable source of truth: a fresh node bootstrapped
    # from the Commit Set sees exactly what survived
    audit = AftNode(store, AftNodeConfig(node_id="routing-audit"))
    duplicates = 0
    anomalies = 0
    incomplete = 0
    tx = audit.start_transaction()
    for i in range(total):
        raw = audit.get(tx, f"cnt/{i}")
        raw2 = audit.get(tx, f"cnt2/{i}")
        count = json.loads(raw)["count"] if raw else 0
        if count == 0:
            incomplete += 1
        duplicates += max(count - 1, 0)
        if raw != raw2:
            anomalies += 1  # fractured pair: the atomic co-write diverged
    audit.abort_transaction(tx)

    out = {
        "policy": "consistent_hash",
        "workflows": total,
        "completed": len(results) + len(wave2_results),
        "killed_node": killed_id,
        "live_nodes_after_replacement": replaced,
        "standby_promoted": replaced == NODES,
        "workflows_retried": retried,
        "steps_memo_resumed": memo_resumes,
        "post_replacement_workflows": wave2,
        "incomplete_counters": incomplete,
        "duplicate_effects": duplicates,
        "anomalies": anomalies,
        "exactly_once": duplicates == 0 and incomplete == 0,
    }
    platform.shutdown()
    cluster.stop()
    return out


def run(quick: bool = True) -> Dict:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    if smoke:
        workflows, kill_workflows = 100, 60
    elif quick:
        workflows, kill_workflows = 400, 200
    else:
        workflows, kill_workflows = 1500, 600

    sweep = [
        _run_policy(p, workflows, THROUGHPUT_TIME_SCALE, seed=7)
        for p in POLICIES
    ]
    by_policy = {row["policy"]: row for row in sweep}
    rr = by_policy["round_robin"]
    kill = _run_kill_midstream(kill_workflows, KILL_TIME_SCALE, seed=23)

    out = {
        "nodes": NODES,
        "zipf_theta": ZIPF_THETA,
        "entities": ENTITIES,
        "keys_per_entity": KEYS_PER_ENTITY,
        "cache_keys_per_node": CACHE_KEYS_PER_NODE,
        "policies": sweep,
        "kill_midstream": kill,
        "headline": {
            "speedup_consistent_hash": round(
                by_policy["consistent_hash"]["steps_per_s"]
                / max(rr["steps_per_s"], 1e-9), 2
            ),
            "speedup_cache_aware": round(
                by_policy["cache_aware"]["steps_per_s"]
                / max(rr["steps_per_s"], 1e-9), 2
            ),
            "hit_rate_round_robin": rr["cluster_cache_hit_rate"],
            "hit_rate_consistent_hash":
                by_policy["consistent_hash"]["cluster_cache_hit_rate"],
            "hit_rate_cache_aware":
                by_policy["cache_aware"]["cluster_cache_hit_rate"],
            "kill_exactly_once": kill["exactly_once"],
            "kill_anomalies": kill["anomalies"],
            "kill_duplicate_effects": kill["duplicate_effects"],
            "standby_promoted": kill["standby_promoted"],
        },
    }
    save("fig_routing", out)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
