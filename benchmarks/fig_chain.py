"""fig_chain (figc): durable cross-workflow chaining under kill-mid-handoff.

The claim (workflow/chain.py): an N-deep chain of workflows — each level's
commit durably triggering the next through the AFT-backed ``q/`` queue —
completes with **zero dropped and zero duplicated triggers** even when the
handoff (the window between claiming a trigger and starting its child) is
killed repeatedly.  The §3.3.1 machinery does all the work: the enqueue
rides the parent's commit record, the claim is a deterministic-UUID
transaction, and the child's UUID *is* the queue entry, so every replay
recommits instead of re-firing.

The baseline is the **unscoped handoff** every ad-hoc pipeline starts with:
effects applied in place, the trigger enqueued by a separate non-idempotent
put, an at-least-once consumer with bounded redelivery.  Killed deliveries
re-run entire children (duplicate effects), and entries that exhaust their
redelivery budget truncate the chain (dropped triggers) — both counted by
the same effect-application audit.

Metric: *effect applications per chain level*.  Each level writes one
logical effect key; AFT-scoped counts committed versions of it (exactly one
⇔ exactly-once), the baseline counts the distinct physical keys its
re-executions scattered.  dropped = levels with 0 applications, duplicates
= levels with > 1.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict

from repro.core import AftCluster, ClusterConfig
from repro.core.gc import LocalGcAgent
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.obs import trace as obs_trace
from repro.obs.checker import check_events
from repro.storage.memory import MemoryStorage
from repro.workflow import (
    ChainConsumerConfig,
    PoolConfig,
    Trigger,
    TxnScope,
    WorkflowConfig,
    WorkflowExecutor,
    WorkflowPool,
    WorkflowSpec,
)

from .common import save

DEPTH = 8            # acceptance: an 8-deep chain survives kill-mid-handoff
HANDOFF_KILL_RATE = 0.3
BASELINE_MAX_DELIVERIES = 2  # bounded redelivery (SQS-style) for the baseline


def _link_spec(chain: int, level: int, unscoped: bool = False) -> WorkflowSpec:
    """One chain link: write this level's effect, trigger the next level."""
    spec = WorkflowSpec("link")

    def body(ctx, chain=chain, level=level):
        if unscoped:
            # distinct physical key per execution: the audit counts how many
            # times this level's effect was (re)applied
            from repro.core.ids import fresh_uuid

            ctx.put(f"chain/eff/{chain}/{level}/{fresh_uuid()}", b"x")
        else:
            ctx.put(f"chain/eff/{chain}/{level}", b"x")
        # the effects-applied-but-trigger-not-yet-staged hazard: unscoped,
        # this either duplicates the level (redelivery) or truncates the
        # chain (budget exhausted); AFT-scoped it is just another retry
        ctx.maybe_fail(site="chain:stage")
        return {"chain": chain, "level": level + 1}

    spec.step("apply", body)
    if level + 1 < DEPTH:
        spec.trigger(Trigger("link", args_from="apply"))
    return spec


def _effect_counts(storage, chains: int, aft: bool) -> Dict:
    dropped = duplicates = 0
    per_level = []
    for c in range(chains):
        counts = []
        for level in range(DEPTH):
            if aft:
                n = len(storage.list_keys(f"d/chain/eff/{c}/{level}/"))
            else:
                n = len(storage.list_keys(f"chain/eff/{c}/{level}/"))
            counts.append(n)
            if n == 0:
                dropped += 1
            elif n > 1:
                duplicates += n - 1
        per_level.append(counts)
    return {
        "dropped_triggers": dropped,
        "duplicate_effects": duplicates,
        "effect_counts": per_level,
    }


# ---------------------------------------------------------------------------
# AFT-scoped: the durable queue through the commit protocol
# ---------------------------------------------------------------------------

def run_aft(chains: int, seed: int) -> Dict:
    cluster = AftCluster(
        MemoryStorage(),
        ClusterConfig(num_nodes=1, start_background_threads=False),
    )
    platform = LambdaPlatform(FaasConfig(
        time_scale=0.0,
        failure_rate=HANDOFF_KILL_RATE,
        failure_sites=("chain:handoff", "chain:claim", "chain:stage"),
        seed=seed,
    ))

    def link_factory(args):
        args = args or {}
        return _link_spec(args.get("chain", 0), args.get("level", 0))

    t0 = time.perf_counter()
    # max_attempts high enough that a child cannot exhaust its retries at
    # the 30% in-body kill rate (0.3^25 ≈ 1e-13) — the figure measures the
    # HANDOFF protocol, not retry-budget exhaustion
    cfg = PoolConfig(max_attempts=25)
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        consumer = pool.attach_chain_consumer(
            {"link": link_factory},
            ChainConsumerConfig(reclaim_after_s=0.0, poll_interval_s=0.002),
            start=False,
        )
        for c in range(chains):
            pool.submit(_link_spec(c, 0), uuid=f"figc-{c}")
        deadline = time.time() + 120
        while time.time() < deadline:
            consumer.step()
            done = sum(
                1 for c in range(chains)
                if cluster.storage.list_keys(f"d/chain/eff/{c}/{DEPTH-1}/")
            )
            if done == chains and consumer.pending() == 0:
                break
            time.sleep(0.001)
        stats = dict(consumer.stats)
    wall = time.perf_counter() - t0

    audit = _effect_counts(cluster.storage, chains, aft=True)
    # GC rider: consumed entries + finished children are reclaimed together
    queue_keys_before = len(cluster.storage.list_keys("d/q/"))
    agent = LocalGcAgent(cluster.live_nodes()[0], workflow_gc_batch=100_000)
    agent.step()
    cluster.fault_manager.config.workflow_marker_ttl_s = 0.0
    cluster.fault_manager.sweep_finished_markers()
    cluster.fault_manager.deleter.drain()
    queue_keys_after = len(cluster.storage.list_keys("d/q/"))
    cluster.stop()
    platform.shutdown()
    return {
        "mode": "aft_queue",
        "chains": chains,
        "depth": DEPTH,
        "wall_s": round(wall, 3),
        "handoff_crashes": stats["handoff_crashes"],
        "claims_taken_over": stats["claims_taken_over"],
        "children_started": stats["children_started"],
        "already_finished_skips": stats["already_finished_skips"],
        "queue_keys_before_gc": queue_keys_before,
        "queue_keys_after_gc": queue_keys_after,
        **audit,
    }


# ---------------------------------------------------------------------------
# baseline: unscoped effects + non-idempotent handoff, bounded redelivery
# ---------------------------------------------------------------------------

def run_baseline(chains: int, seed: int) -> Dict:
    storage = MemoryStorage()
    platform = LambdaPlatform(FaasConfig(
        time_scale=0.0,
        failure_rate=HANDOFF_KILL_RATE,
        failure_sites=("chain:handoff", "chain:stage"),
        seed=seed,
    ))
    ex = WorkflowExecutor(
        platform, storage=storage,
        config=WorkflowConfig(scope=TxnScope.NONE, memoize=False,
                              max_attempts=1),
    )
    t0 = time.perf_counter()
    stats = {"handoff_crashes": 0, "lost_entries": 0}

    def drive(args) -> None:
        """One delivery: run the child (effects land in place, the next
        trigger staged non-atomically), then the completion ack the
        injected kill also targets."""
        args = args or {}
        ex.run(_link_spec(args.get("chain", 0), args.get("level", 0),
                          unscoped=True))
        platform.maybe_fail(site="chain:handoff")  # crash before ack'ing

    def deliver(args) -> None:
        """At-least-once with bounded redelivery: a crashed delivery
        re-runs the WHOLE child (duplicate effects); an exhausted budget
        abandons the entry (its staged-but-never-driven successors are the
        dropped triggers)."""
        for _delivery in range(BASELINE_MAX_DELIVERIES):
            try:
                drive(args)
                return
            except Exception:
                stats["handoff_crashes"] += 1
        stats["lost_entries"] += 1

    for c in range(chains):
        deliver({"chain": c, "level": 0})  # the seed requests
    done = set()
    progress = True
    while progress:
        progress = False
        for raw_key in storage.list_keys("q/"):
            if raw_key in done:
                continue
            done.add(raw_key)
            progress = True
            payload = json.loads(storage.get(raw_key))
            deliver(payload.get("args"))
    wall = time.perf_counter() - t0
    platform.shutdown()
    return {
        "mode": "unscoped_handoff",
        "chains": chains,
        "depth": DEPTH,
        "wall_s": round(wall, 3),
        "handoff_crashes": stats["handoff_crashes"],
        "entries_lost_to_redelivery_budget": stats["lost_entries"],
        **_effect_counts(storage, chains, aft=False),
    }


def run(quick: bool = True) -> Dict:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    chains = 2 if smoke else (6 if quick else 20)
    # trace the whole chained run (handoffs included) and replay it through
    # the offline invariant checker: kill-mid-handoff must leave a log the
    # checker still scores clean
    prev_tracer = obs_trace.get_tracer()
    tracer = obs_trace.enable(
        path=os.environ.get(obs_trace.TRACE_FILE_ENV), capacity=500_000
    )
    try:
        aft = run_aft(chains, seed=11)
    finally:
        obs_trace.set_tracer(prev_tracer)
        tracer.close()
    checked = check_events(tracer.events())
    aft["trace_events"] = len(tracer.events())
    aft["trace_violations"] = len(checked.violations)
    baseline = run_baseline(chains, seed=11)
    out = {
        "depth": DEPTH,
        "chains": chains,
        "handoff_kill_rate": HANDOFF_KILL_RATE,
        "aft": aft,
        "baseline": baseline,
        "headline": {
            "aft_dropped": aft["dropped_triggers"],
            "aft_duplicates": aft["duplicate_effects"],
            "aft_exactly_once": (
                aft["dropped_triggers"] == 0
                and aft["duplicate_effects"] == 0
            ),
            "aft_handoff_crashes_survived": aft["handoff_crashes"],
            "baseline_dropped": baseline["dropped_triggers"],
            "baseline_duplicates": baseline["duplicate_effects"],
            "baseline_anomalous": (
                baseline["dropped_triggers"] > 0
                or baseline["duplicate_effects"] > 0
            ),
            "queue_reclaimed_by_gc": (
                aft["queue_keys_after_gc"] < aft["queue_keys_before_gc"]
            ),
            "trace_violations": aft["trace_violations"],
        },
    }
    save("fig_chain", out)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
