"""fig_hotpath: the metadata hot path at memory speed (figh).

Measures the PR-10 rebuild of the node-local metadata path — striped
``CommitSetCache``, incremental Algorithm-1 reads, encode-once record
fan-out, O(1) LRU ``DataCache`` — against a **pre-PR proxy** baseline:
the same code configured back to the old shape (``cache_stripes=1`` makes
every section the coarse lock, ``incremental_reads=False`` selects the
retained reference ``atomic_read_select`` that rescans the read set per
read, and ``set_encode_cache(False)`` re-serializes records at every
fan-out point).  The old FIFO ``DataCache`` is not restorable by knob; its
effect is covered by the regression test, not this benchmark.

Two arms, each run under both configs on one node over ``MemoryStorage``
(zero storage latency, so metadata CPU *is* the workload):

* **contended** — 8 closed-loop driver threads; each transaction reads 16
  cowritten pairs (32 reads) and atomically rewrites one pair (2 writes).
  Headline: steps/sec (committed transactions per second) ratio.  Python's
  GIL means the win must come from doing *less work per read* (O(R) vs
  O(R²) lower-bound maintenance, candidate-tail slices vs full-list
  copies, fewer contended lock handoffs) — not from parallelism.
* **long** — single-threaded 64-read transactions; headline: mean
  ``read.resolve`` latency (selection only, storage fetch excluded) from
  the node registry's histogram.

Safety is audited, not assumed: every pair read inside a transaction must
resolve to the *same* version (both keys are only ever written together,
so Definition 1 forces tid equality — a mismatch is a fractured read), and
a separate untimed traced pass replays its whole event stream through the
offline checker at zero violations.
"""

from __future__ import annotations

import json
import os
import random
import threading
import time
from typing import Dict

from repro.core import (
    AftNode,
    AftNodeConfig,
    ReadAbortError,
    encode_cache_stats,
    set_encode_cache,
)
from repro.obs import trace as obs_trace
from repro.obs.checker import check_events
from repro.storage import MemoryStorage

from .common import save

THREADS = 8
PAIRS = 64                  # shared keyspace: p/<i> + q/<i> cowritten pairs
READS_PER_TXN_PAIRS = 16    # 16 pairs -> 32 reads per contended transaction
LONG_READS = 64             # reads per long-arm transaction (32 pairs)

BASELINE = {"cache_stripes": 1, "incremental_reads": False}
OPTIMIZED = {"cache_stripes": 16, "incremental_reads": True}


def _make_node(overrides: Dict, name: str) -> AftNode:
    cfg = AftNodeConfig(node_id=name, enable_data_cache=True,
                        txn_timeout_s=60.0, **overrides)
    return AftNode(MemoryStorage(), cfg)


def _seed_pairs(node: AftNode, pairs: int) -> None:
    """Give every pair an initial atomically-cowritten version."""
    for i in range(pairs):
        tx = node.start_transaction()
        payload = json.dumps({"pair": i, "gen": 0}).encode()
        node.put(tx, f"p/{i}", payload)
        node.put(tx, f"q/{i}", payload)
        node.commit_transaction(tx)
        node.release_transaction(tx)


def _txn_step(node: AftNode, rng: random.Random, n_pairs: int,
              stats: Dict) -> None:
    """One transaction: read ``n_pairs`` pairs (audited), rewrite one."""
    tx = node.start_transaction()
    try:
        chosen = rng.sample(range(PAIRS), n_pairs)
        for i in chosen:
            _v1, t1 = node.get_versioned(tx, f"p/{i}")
            _v2, t2 = node.get_versioned(tx, f"q/{i}")
            # p/<i> and q/<i> are only ever written together: Definition 1
            # makes unequal versions inside one transaction a fractured read
            if t1 != t2:
                stats["anomalies"] += 1
        w = chosen[0]
        payload = json.dumps(
            {"pair": w, "gen": rng.randrange(1 << 30)}).encode()
        node.put(tx, f"p/{w}", payload)
        node.put(tx, f"q/{w}", payload)
        node.commit_transaction(tx)
        stats["commits"] += 1
    except ReadAbortError:
        node.abort_transaction(tx)   # §3.6 staleness abort: retry-able
        stats["aborts"] += 1
    finally:
        node.release_transaction(tx)


def _run_contended(overrides: Dict, txns_per_thread: int,
                   seed: int) -> Dict:
    node = _make_node(overrides, f"hot-{overrides['cache_stripes']}")
    _seed_pairs(node, PAIRS)
    stats = {"commits": 0, "aborts": 0, "anomalies": 0}
    lock = threading.Lock()
    barrier = threading.Barrier(THREADS)

    def driver(tid: int) -> None:
        rng = random.Random(seed * 100 + tid)
        local = {"commits": 0, "aborts": 0, "anomalies": 0}
        barrier.wait()
        for _ in range(txns_per_thread):
            _txn_step(node, rng, READS_PER_TXN_PAIRS, local)
        with lock:
            for k, v in local.items():
                stats[k] += v

    threads = [threading.Thread(target=driver, args=(i,), daemon=True)
               for i in range(THREADS)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    snap = node.registry.snapshot()
    resolve = snap.get("read.resolve", {})
    out = {
        "threads": THREADS,
        "txns_per_thread": txns_per_thread,
        "commits": stats["commits"],
        "aborts": stats["aborts"],
        "anomalies": stats["anomalies"],
        "wall_s": round(wall, 3),
        "steps_per_s": round(stats["commits"] / max(wall, 1e-9), 1),
        "read_resolve_mean_ms": _hist_mean(resolve),
        "read_resolve_p99_ms": resolve.get("p99_ms", 0.0),
        "cache_lock_acquires": snap.get("cache_lock_acquires", 0),
        "cache_lock_contended": snap.get("cache_lock_contended", 0),
        "cache_lock_wait_ms": round(snap.get("cache_lock_wait_ms", 0.0), 2),
    }
    return out


def _run_long(overrides: Dict, txns: int, seed: int) -> Dict:
    node = _make_node(overrides, f"long-{overrides['cache_stripes']}")
    _seed_pairs(node, PAIRS)
    rng = random.Random(seed)
    stats = {"commits": 0, "aborts": 0, "anomalies": 0}
    t0 = time.perf_counter()
    for _ in range(txns):
        _txn_step(node, rng, LONG_READS // 2, stats)
    wall = time.perf_counter() - t0
    resolve = node.registry.snapshot().get("read.resolve", {})
    return {
        "txns": txns,
        "reads_per_txn": LONG_READS,
        "commits": stats["commits"],
        "aborts": stats["aborts"],
        "anomalies": stats["anomalies"],
        "wall_s": round(wall, 3),
        "read_resolve_mean_ms": _hist_mean(resolve),
        "read_resolve_p99_ms": resolve.get("p99_ms", 0.0),
        "resolve_count": resolve.get("count", 0),
    }


def _hist_mean(summary: Dict) -> float:
    count = summary.get("count", 0)
    if not count:
        return 0.0
    return round(float(summary.get("sum_ms", 0.0)) / count, 5)


def run(quick: bool = True) -> Dict:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    if smoke:
        txns_per_thread, long_txns = 30, 20
    elif quick:
        txns_per_thread, long_txns = 120, 60
    else:
        txns_per_thread, long_txns = 500, 250

    # -- baseline (pre-PR proxy): coarse lock, reference reads, no encode
    # cache.  Encode caching is process-global; restore before the
    # optimized arms.
    set_encode_cache(False)
    try:
        base_contended = _run_contended(BASELINE, txns_per_thread, seed=7)
        base_long = _run_long(BASELINE, long_txns, seed=13)
    finally:
        set_encode_cache(True)

    # -- optimized: striped cache, incremental Algorithm 1, encode-once
    opt_contended = _run_contended(OPTIMIZED, txns_per_thread, seed=7)
    opt_long = _run_long(OPTIMIZED, long_txns, seed=13)

    # -- traced audit pass (untimed): rerun the optimized contended shape
    # under the tracer and replay its event stream through the offline
    # checker.  Kept out of the timed arms so neither config pays tracing
    # overhead in the headline.
    prev_tracer = obs_trace.get_tracer()
    tracer = obs_trace.enable(
        path=os.environ.get(obs_trace.TRACE_FILE_ENV), capacity=500_000)
    try:
        audit = _run_contended(
            OPTIMIZED, max(txns_per_thread // 2, 10), seed=23)
    finally:
        obs_trace.set_tracer(prev_tracer)
        tracer.close()
    checked = check_events(tracer.events())

    enc = encode_cache_stats()  # process-wide, see node gauge docs
    speedup = round(
        opt_contended["steps_per_s"] / max(base_contended["steps_per_s"],
                                           1e-9), 2)
    resolve_ratio = round(
        base_long["read_resolve_mean_ms"]
        / max(opt_long["read_resolve_mean_ms"], 1e-9), 2)
    total_anomalies = (
        base_contended["anomalies"] + base_long["anomalies"]
        + opt_contended["anomalies"] + opt_long["anomalies"]
        + audit["anomalies"])

    out = {
        "pairs": PAIRS,
        "reads_per_contended_txn": READS_PER_TXN_PAIRS * 2,
        "baseline_knobs": {**BASELINE, "encode_cache": False},
        "optimized_knobs": {**OPTIMIZED, "encode_cache": True},
        "contended": {"baseline": base_contended,
                      "optimized": opt_contended},
        "long": {"baseline": base_long, "optimized": opt_long},
        "traced_audit": audit,
        "encode_cache": enc,
        "trace_events": len(tracer.events()),
        "headline": {
            "speedup_steps_per_s": speedup,
            "baseline_steps_per_s": base_contended["steps_per_s"],
            "optimized_steps_per_s": opt_contended["steps_per_s"],
            "read_resolve_mean_ratio": resolve_ratio,
            "baseline_resolve_mean_ms": base_long["read_resolve_mean_ms"],
            "optimized_resolve_mean_ms": opt_long["read_resolve_mean_ms"],
            "optimized_lock_wait_ms": opt_contended["cache_lock_wait_ms"],
            "anomalies": total_anomalies,
            "aborts": (base_contended["aborts"] + opt_contended["aborts"]
                       + base_long["aborts"] + opt_long["aborts"]),
            "checker_violations": len(checked.violations),
        },
    }
    save("fig_hotpath", out)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
