"""Fig 8: distributed scalability — throughput vs AFT node count with 10
clients per node; within-90%-of-ideal check (ideal = nodes × single-node
throughput)."""

from __future__ import annotations

from typing import Dict

from repro.faas.workload import run_workload

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg


def run(quick: bool = True) -> Dict:
    clients_per_node = 10
    per_client = 8 if quick else 1000
    # distributed scaling must stay below the single-process emulation's
    # python-work ceiling (~1k txn/s) to expose the *protocol's* scaling:
    # mild compression keeps total demand in the linear region.
    ts = 5.0
    out: Dict[str, Dict] = {}
    base_tps = None
    for nodes in (1, 2, 4, 8):
        cluster = make_cluster(engine("dynamodb", ts), nodes=nodes,
                               time_scale=ts)
        cfg = workload_cfg(zipf=1.5, time_scale=ts, seed=nodes)
        res = run_workload("aft", cfg=cfg, clients=clients_per_node * nodes,
                           txns_per_client=per_client, cluster=cluster)
        s = res.summary()
        if nodes == 1:
            base_tps = s["tps"]
        s["ideal_tps"] = round(base_tps * nodes, 1)
        s["fraction_of_ideal"] = round(s["tps"] / max(s["ideal_tps"], 1e-9), 3)
        out[f"nodes_{nodes}"] = s
        cluster.stop()
    save("fig8_distributed", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
