"""Shared benchmark plumbing: cluster construction, result IO, quick-mode
scaling.

Latency/throughput *shapes* reproduce the paper's figures; absolute numbers
are driven by the simulated engine latency models (calibrated to Fig 2/3 of
the paper) compressed by ``time_scale`` so the whole suite runs in minutes
on this container.  ``--full`` in run.py lifts the compression.
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, Optional

from repro.core import AftCluster, AftNodeConfig, ClusterConfig
from repro.faas.platform import FaasConfig
from repro.faas.workload import WorkloadConfig, run_workload
from repro.storage.simulated import make_engine

RESULTS = pathlib.Path(__file__).resolve().parent / "results"

# compress simulated storage/faas latencies (1 sim-ms → 0.03 real-ms).
QUICK_TIME_SCALE = 0.03


def save(name: str, payload: Dict) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{name}.json"
    out.write_text(json.dumps(payload, indent=1, default=str))
    return out


def make_cluster(engine, *, nodes: int = 1, data_cache: bool = True,
                 standby: int = 0, time_scale: float = QUICK_TIME_SCALE,
                 gc_interval_s: float = 0.2,
                 fast_failover: bool = False,
                 router=None,
                 data_cache_bytes: Optional[int] = None,
                 node_overrides: Optional[Dict] = None,
                 cluster_overrides: Optional[Dict] = None,
                 background: bool = True) -> AftCluster:
    """``node_overrides`` patches extra AftNodeConfig fields (e.g. the I/O
    pipeline knobs ``io_workers`` / ``enable_io_pipeline`` in fig_async);
    ``cluster_overrides`` does the same for ClusterConfig (elastic knobs
    like ``join_ramp_step`` / ``multicast_eager_push`` in fig_elastic);
    ``background=False`` skips the multicast/GC/fault-manager threads for
    single-node latency studies where they only add scheduler noise."""
    from repro.core import FaultManagerConfig

    node_cfg = AftNodeConfig(
        enable_data_cache=data_cache,
        multicast_interval_s=0.05,
        gc_interval_s=gc_interval_s,
        txn_timeout_s=30.0,
    )
    if data_cache_bytes is not None:
        node_cfg.data_cache_bytes = data_cache_bytes
    for k, v in (node_overrides or {}).items():
        setattr(node_cfg, k, v)
    fm = FaultManagerConfig(scan_interval_s=0.1, gc_interval_s=0.15,
                            heartbeat_interval_s=0.3 if fast_failover else 1.0,
                            heartbeat_misses=3)
    cfg = ClusterConfig(num_nodes=nodes, standby_nodes=standby, node=node_cfg,
                        fault_manager=fm,
                        replacement_delay_s=1.0 * time_scale * 33,
                        routing=router,
                        start_background_threads=background)
    for k, v in (cluster_overrides or {}).items():
        setattr(cfg, k, v)
    cluster = AftCluster(engine, cfg)
    if background:
        cluster.start()
    return cluster


def workload_cfg(*, zipf: float = 1.0, functions: int = 2, reads: int = 2,
                 writes: int = 1, num_keys: int = 1000,
                 time_scale: float = QUICK_TIME_SCALE,
                 seed: int = 0) -> WorkloadConfig:
    return WorkloadConfig(
        num_keys=num_keys, zipf=zipf, functions_per_txn=functions,
        reads_per_function=reads, writes_per_function=writes,
        value_bytes=4096,
        faas=FaasConfig(time_scale=time_scale, seed=seed),
        seed=seed)


def engine(name: str, time_scale: float = QUICK_TIME_SCALE, seed: int = 0):
    return make_engine(name, time_scale=time_scale, seed=seed)
