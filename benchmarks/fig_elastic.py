"""fig_elastic: elastic membership under a diurnal + flash-crowd trace (fige).

Two claims about the elastic cluster layer (ISSUE 9):

1. **autoscaling holds tail latency** — a diurnal trace (night → morning →
   flash crowd → evening) drives paced async-commit load through each
   node's bounded storage I/O pipeline.  A *static* 2-node cluster
   saturates during the flash crowd (closed-loop p99 grows with per-node
   queueing), while the *autoscaled* cluster — an :class:`Autoscaler`
   watching the obs registry's load gauges AND its merged commit-latency
   p99 — joins ramping nodes (JOINING → LIVE with warm-up handoff) until
   fleet p99 is back under target, holding it roughly flat.  When the
   crowd leaves, it scales back down by *draining* (never killing).

2. **migration is safe under faults** — a kill-during-migration arm runs a
   counter+mirror workflow stream, starts a join (warm-up handoff in
   flight), hard-kills a donor node mid-migration, then drains a node
   under load.  The audit replays every counter from a fresh node: zero
   incomplete, zero duplicate effects, zero fractured co-writes — and the
   offline trace checker replays the whole benchmark's event stream with
   zero violations.

Methodology: load is closed-loop (each client thread submits one
transaction at a time), so once a node's pipeline workers are busy,
latency is proportional to per-node concurrency — exactly the signal an
operator's p99 dashboard would show.  Both arms run the same trace, seeds,
and engine; the autoscaler is the only variable.
"""

from __future__ import annotations

import json
import os
import random
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core import (
    AftNode,
    AftNodeConfig,
    Autoscaler,
    AutoscalerConfig,
    NodeLifecycle,
    PlacementHint,
)
from repro.core.routing import ConsistentHashRouter
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.obs import trace as obs_trace
from repro.obs.checker import check_events
from repro.storage.simulated import LatencyModel, SimulatedEngine
from repro.workflow import PoolConfig, TxnScope, WorkflowPool, WorkflowSpec

from .common import engine, make_cluster, save

BASE_NODES = 2
MAX_NODES = 12
IO_WORKERS = 4           # read/probe threads per node
KEYS = 1024
VALUE_BYTES = 512
# latency under study is simulated-storage-bound queueing; run the trace
# much less compressed than the suite default so pipeline service time
# (a storage sleep, which parallelizes) dwarfs per-op Python overhead
# (which doesn't — this container has one core, so adding nodes only
# helps when the capacity bound is sleeping workers, as it is for a real
# AFT deployment bound on storage round-trips)
TRACE_TIME_SCALE = 9.0
MIGRATION_TIME_SCALE = 0.15
# the autoscaler's flash-crowd objective: scale up while commit p99 is
# above this (and load confirms it's demand, not a blip) — the gated
# steady-state p99 then converges to ~this target by control, which is
# what makes the headline ratio reproducible run to run
P99_TARGET_MS = 420.0

# diurnal + flash-crowd trace: (phase, closed-loop clients, duration
# multiplier).  The gated phases feed the headline p99 ratios and run
# longer so their p99 rests on enough samples to be stable; warmup
# absorbs cold-start transients (connection/cache/thread spin-up) so the
# night baseline measures steady low-load service, and the onset phase
# is the autoscaler's adaptation window — reported, not gated.
TRACE = (
    ("warmup", 6, 1.0),         # uncounted: startup transients
    ("night", 6, 2.5),          # gated: the low-load baseline
    ("morning", 12, 1.0),
    ("flash_onset", 24, 1.0),
    ("flash_steady", 24, 2.5),  # gated: the saturation probe
    ("evening", 6, 1.0),        # scale-down window
)
GATED = ("night", "flash_steady")


def _trace_engine(seed: int) -> SimulatedEngine:
    """Provisioned-capacity cloud KVS: dynamodb medians, tight tails.
    The trace arms measure *queueing* under a flash crowd — with the
    stock sigma the engine's own lognormal tail lottery dominates both
    phases' p99 on a run this short and drowns the signal."""
    return SimulatedEngine(
        read=LatencyModel(base_ms=3.6, per_kb_ms=0.02, sigma=0.12,
                          batch_base_ms=4.8, batch_per_item_ms=0.35),
        write=LatencyModel(base_ms=4.2, per_kb_ms=0.02, sigma=0.12,
                           batch_base_ms=5.5, batch_per_item_ms=0.45),
        overwrite_visibility_lag_ms=25.0,
        time_scale=TRACE_TIME_SCALE, seed=seed, name="dynamodb-prov",
    )


def _pct(samples: List[float], q: float) -> float:
    if not samples:
        return 0.0
    ordered = sorted(samples)
    idx = min(len(ordered) - 1, int(round(q * (len(ordered) - 1))))
    return ordered[idx]


def _client_loop(cluster, phase_state: Dict, out: List[Tuple[str, float]],
                 stop: threading.Event, seed: int) -> None:
    """One closed-loop client: route by key, async-commit through the
    owner's pipeline, record (phase, latency).  Membership churns under
    us — a node retiring between route and commit surfaces as an
    exception, and the op simply retries on the refreshed ring."""
    rng = random.Random(seed)
    while not stop.is_set():
        key = f"k/{rng.randrange(KEYS)}"
        t0 = time.perf_counter()
        try:
            node = cluster.pick_node(PlacementHint(keys=(key,)))
            tx = node.start_transaction()
            node.put(tx, key, b"v" * VALUE_BYTES)
            node.commit_transaction_async(tx).result(timeout=120)
            node.release_transaction(tx)
        except Exception:
            time.sleep(0.001)  # retired/killed mid-op: retry, fresh ring
            continue
        out.append((phase_state["phase"], time.perf_counter() - t0))
        # a little client think time decorrelates arrivals — bursts of
        # lock-step submissions would otherwise manufacture p99 queueing
        # that no open-world trace exhibits
        time.sleep(rng.uniform(0.0, 0.03))


def _run_trace(autoscale: bool, phase_s: float, seed: int) -> Dict:
    store = _trace_engine(seed)
    cluster = make_cluster(
        store, nodes=BASE_NODES, time_scale=TRACE_TIME_SCALE,
        # 256 vnodes: at 10 nodes the default 64 leaves ~1.5x ring-share
        # skew, which shows up directly as the hottest node's p99
        router=ConsistentHashRouter(vnodes=256),
        # a small flush page + one flush on the wire bounds per-node commit
        # throughput the way a provisioned-capacity table does — the flash
        # crowd must then either queue (static) or spread (autoscaled)
        node_overrides={
            "io_workers": IO_WORKERS,
            "flush_max_items": 4,
            "flush_concurrency": 1,
            # batched announcement rounds only: per-commit eager push costs
            # O(peers) Python per commit, which at 10 nodes on one core
            # competes with the very ops under measurement
            "multicast_interval_s": 0.15,
        },
        cluster_overrides={"multicast_eager_push": False},
    )
    # faster weight ramp: the flash crowd is seconds, not minutes
    cluster.config.join_ramp_step = 0.5
    scaler: Optional[Autoscaler] = None
    if autoscale:
        scaler = Autoscaler(cluster, cluster.fault_manager, AutoscalerConfig(
            min_nodes=BASE_NODES, max_nodes=MAX_NODES,
            # AND-gated triggers: the load floor confirms there is real
            # demand, the p99 gate is the objective — night runs hot per
            # node but FAST (no queueing), so it must not scale; the flash
            # crowd's queueing pushes commit p99 over target and the
            # cluster widens until p99 is back under it
            scale_up_load=3.5,
            scale_up_p99_ms=P99_TARGET_MS,
            scale_down_load=2.0,
            up_ticks=1, down_ticks=4,
            up_cooldown_s=0.05, down_cooldown_s=0.2,
            # rebalance when one arc carries 3x the mean load — skew is a
            # split problem, not a fleet-width problem
            split_ratio=3.0, split_cooldown_s=1.0,
        ))

    samples: List[Tuple[str, float]] = []
    phase_state = {"phase": TRACE[0][0]}
    nodes_seen = {TRACE[0][0]: len(cluster.live_nodes())}
    clients_of = {p: c for p, c, _m in TRACE}
    max_nodes = len(cluster.live_nodes())
    threads: List[threading.Thread] = []
    stops: List[threading.Event] = []

    def set_clients(n: int) -> None:
        while len(threads) > n:
            stops.pop().set()
            threads.pop()
        while len(threads) < n:
            stop = threading.Event()
            t = threading.Thread(
                target=_client_loop,
                args=(cluster, phase_state, samples, stop,
                      seed * 1000 + len(threads)),
                daemon=True,
            )
            stops.append(stop)
            threads.append(t)
            t.start()

    for phase, clients, dur_mult in TRACE:
        phase_state["phase"] = phase
        set_clients(clients)
        deadline = time.perf_counter() + phase_s * dur_mult
        while time.perf_counter() < deadline:
            if scaler is not None:
                scaler.step()
            # 10 Hz: each tick walks every node's registry — on this
            # container that CPU bill lands on the same core serving ops
            time.sleep(0.1)
        nodes_seen[phase] = len(cluster.live_nodes())
        max_nodes = max(max_nodes, len(cluster.live_nodes()))
    for stop in stops:
        stop.set()
    for t in threads:
        t.join(timeout=120)
    # post-trace: the crowd is gone — let the scaler walk membership all
    # the way back down (each drain serializes: decide → drain → retire)
    drained_alive = True
    if scaler is not None:
        deadline = time.perf_counter() + 60
        while time.perf_counter() < deadline:
            scaler.step()
            draining = any(
                cluster.lifecycle_of(n) is NodeLifecycle.DRAINING
                for n in cluster.live_nodes()
            )
            if len(cluster.live_nodes()) <= BASE_NODES and not draining:
                break
            time.sleep(0.02)
        drained = [e for e in scaler.events if e["event"] == "scale-down"]
        for event in drained:
            node = next(
                (n for n in cluster.nodes if n.node_id == event["node"]), None
            )
            # a drained node object stays alive (graceful) even after it
            # leaves membership — a killed one would have alive=False
            if node is not None and not node.alive:
                drained_alive = False

    phases = {}
    for phase, _clients, _mult in TRACE:
        lat = [dt for p, dt in samples if p == phase]
        phases[phase] = {
            "clients": clients_of[phase],
            "ops": len(lat),
            "p50_ms": round(_pct(lat, 0.50) * 1e3, 2),
            "p99_ms": round(_pct(lat, 0.99) * 1e3, 2),
            "nodes_at_end": nodes_seen[phase],
        }
    out = {
        "arm": "autoscaled" if autoscale else "static",
        "phases": phases,
        "max_nodes": max_nodes,
        "final_nodes": len(cluster.live_nodes()),
        "total_ops": len(samples),
    }
    if scaler is not None:
        out["scaler_events"] = [
            {k: v for k, v in e.items() if k != "at"} for e in scaler.events
        ]
        out["drained_not_killed"] = drained_alive
    cluster.stop()
    return out


# ------------------------------------------------------- migration safety arm
def counter_spec(wf: int) -> WorkflowSpec:
    """RMW a private counter AND an atomically co-written mirror — the
    exactly-once + fractured-state probe (same audit as fig_routing)."""
    spec = WorkflowSpec(f"el-{wf}")

    def bump(ctx):
        raw = ctx.get(f"elc/{wf}")
        count = json.loads(raw)["count"] if raw else 0
        ctx.maybe_fail()
        payload = json.dumps({"count": count + 1}).encode()
        ctx.put(f"elc/{wf}", payload)
        ctx.put(f"elc2/{wf}", payload)  # must never diverge from elc/
        return count + 1

    spec.step("bump", bump, reads=(f"elc/{wf}",))
    return spec


def _settle_lifecycle(cluster, want, node, timeout_s: float = 30.0) -> bool:
    deadline = time.perf_counter() + timeout_s
    while time.perf_counter() < deadline:
        cluster.advance_lifecycle()
        if cluster.lifecycle_of(node) is want:
            return True
        time.sleep(0.01)
    return cluster.lifecycle_of(node) is want


def _run_migration_arm(workflows: int, seed: int) -> Dict:
    """Join a node mid-stream, kill a donor while the joiner is still
    warming up, then drain a node under load — and prove every counter
    landed exactly once with no fractured pairs."""
    ts = MIGRATION_TIME_SCALE
    store = engine("dynamodb", ts, seed=seed)
    cluster = make_cluster(
        store, nodes=3, time_scale=ts, fast_failover=True,
        router="consistent_hash",
    )
    platform = LambdaPlatform(
        FaasConfig(time_scale=ts, max_workers=32, seed=seed)
    )
    cfg = PoolConfig(
        scope=TxnScope.WORKFLOW, max_attempts=25,
        max_inflight_steps=256, max_admitted_workflows=8192,
    )
    wave2 = max(workflows // 3, 8)
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [pool.submit(counter_spec(i)) for i in range(workflows)]
        deadline = time.perf_counter() + 30
        while (
            sum(t.done() for t in tickets) < workflows // 3
            and time.perf_counter() < deadline
        ):
            time.sleep(0.005)
        # migration starts: a ramping joiner begins warm-up handoff ...
        joiner = cluster.join_node(ramp=True)
        joining_at_kill = (
            cluster.lifecycle_of(joiner) is NodeLifecycle.JOINING
        )
        # ... and a donor dies before the joiner reaches LIVE
        killed_id = cluster.kill_node(1).node_id
        join_completed = _settle_lifecycle(cluster, NodeLifecycle.LIVE, joiner)
        results = [t.result(timeout=600) for t in tickets]
        retried = sum(1 for r in results if r.attempts > 1)
        memo_resumes = sum(r.steps_memoized for r in results)
        # scale-down under load: drain (never kill) while wave 2 runs
        wave2_tickets = [
            pool.submit(counter_spec(workflows + i)) for i in range(wave2)
        ]
        victim = cluster.live_nodes()[-1]
        cluster.drain_node(victim, wait=False)
        wave2_results = [t.result(timeout=600) for t in wave2_tickets]
        deadline = time.perf_counter() + 30
        while (
            cluster.lifecycle_of(victim) is not NodeLifecycle.RETIRED
            and time.perf_counter() < deadline
        ):
            cluster.advance_lifecycle()
            time.sleep(0.01)
        drained_not_killed = (
            cluster.lifecycle_of(victim) is NodeLifecycle.RETIRED
            and victim.alive
        )

    total = workflows + wave2
    # audit from the durable source of truth: a fresh node bootstrapped
    # from the Commit Set sees exactly what survived
    audit = AftNode(store, AftNodeConfig(node_id="elastic-audit"))
    duplicates = anomalies = incomplete = 0
    tx = audit.start_transaction()
    for i in range(total):
        raw = audit.get(tx, f"elc/{i}")
        raw2 = audit.get(tx, f"elc2/{i}")
        count = json.loads(raw)["count"] if raw else 0
        if count == 0:
            incomplete += 1
        duplicates += max(count - 1, 0)
        if raw != raw2:
            anomalies += 1  # fractured pair: the atomic co-write diverged
    audit.abort_transaction(tx)

    out = {
        "workflows": total,
        "completed": len(results) + len(wave2_results),
        "killed_node": killed_id,
        "joining_at_kill": joining_at_kill,
        "join_completed": join_completed,
        "workflows_retried": retried,
        "steps_memo_resumed": memo_resumes,
        "drained_not_killed": drained_not_killed,
        "incomplete_counters": incomplete,
        "duplicate_effects": duplicates,
        "anomalies": anomalies,
        "exactly_once": duplicates == 0 and incomplete == 0,
    }
    platform.shutdown()
    cluster.stop()
    return out


def run(quick: bool = True) -> Dict:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    if smoke:
        phase_s, mig_workflows = 3.0, 45
    elif quick:
        phase_s, mig_workflows = 4.0, 150
    else:
        phase_s, mig_workflows = 8.0, 600

    prev_tracer = obs_trace.get_tracer()
    tracer = obs_trace.enable(
        path=os.environ.get(obs_trace.TRACE_FILE_ENV), capacity=500_000
    )
    try:
        static = _run_trace(autoscale=False, phase_s=phase_s, seed=11)
        autoscaled = _run_trace(autoscale=True, phase_s=phase_s, seed=11)
        migration = _run_migration_arm(mig_workflows, seed=29)
    finally:
        obs_trace.set_tracer(prev_tracer)
        tracer.close()
    checked = check_events(tracer.events())

    def deg(arm: Dict) -> float:
        base = max(arm["phases"]["night"]["p99_ms"], 1e-9)
        return round(arm["phases"]["flash_steady"]["p99_ms"] / base, 2)

    out = {
        "base_nodes": BASE_NODES,
        "max_nodes": MAX_NODES,
        "io_workers": IO_WORKERS,
        "trace": [{"phase": p, "clients": c, "dur_mult": m}
                  for p, c, m in TRACE],
        "static": static,
        "autoscaled": autoscaled,
        "migration": migration,
        "trace_events": len(tracer.events()),
        "checker_violations": len(checked.violations),
        "headline": {
            # the two gated ratios: flash-crowd p99 over the arm's own
            # night baseline
            "static_p99_degradation": deg(static),
            "autoscaled_p99_degradation": deg(autoscaled),
            "autoscaled_peak_p99_ms":
                autoscaled["phases"]["flash_steady"]["p99_ms"],
            "static_peak_p99_ms": static["phases"]["flash_steady"]["p99_ms"],
            "autoscaled_max_nodes": autoscaled["max_nodes"],
            "scaled_back_down": autoscaled["final_nodes"] <= BASE_NODES + 1,
            "drained_not_killed": (
                autoscaled.get("drained_not_killed", True)
                and migration["drained_not_killed"]
            ),
            "migration_exactly_once": migration["exactly_once"],
            "migration_anomalies": migration["anomalies"],
            "checker_violations": len(checked.violations),
        },
    }
    save("fig_elastic", out)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
