"""Fig 5: read/write ratio — 10-IO transactions, reads from 0% to 100%,
AFT over DynamoDB and Redis.

Extended with a **read-heavy fast-lane arm**: the same read-dominated
regime driven through the workflow pool, comparing the gossip-fed
read-only lane (``PoolConfig.read_only_lane``) on vs. off.  Read-only
steps on the fast lane skip the commit record, the ``u/`` idempotence
index, and the memo write — on a ≥ 80%-reads mix that is most of the
write traffic, so steps/sec should at least double.  Every reader step
doubles as a read-atomicity audit (both keys of a cowritten pair must
carry identical payloads), and a snapshot mini-arm exercises the
bounded-staleness lane on write-once keys.  CI runs this arm under
``REPRO_TRACE_FILE`` and replays the trace through the offline checker
(read-atomicity, read-durability, snapshot-bound invariants)."""

from __future__ import annotations

import os
import time
from typing import Dict, List

from repro.core import SnapshotUnavailable
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.faas.workload import run_workload
from repro.obs import trace as obs_trace
from repro.obs.checker import check_events
from repro.workflow import PoolConfig, TxnScope, WorkflowPool, WorkflowSpec

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg

# the read-heavy arm runs less compressed than the rw grid: the quantity
# under study (per-step commit IO) would otherwise vanish into interpreter
# noise (same rationale as fig_pool's POOL_TIME_SCALE)
LANE_TIME_SCALE = 0.15
# cowritten pairs cycle over a bounded keyspace so supersedence + GC stay
# active under the audit, mirroring the property-test harness
PAIR_KEYSPACE = 16
# 1 writer step + READERS read-only steps per workflow → 90% reads
READERS_PER_WF = 9


def build_read_heavy_spec(wf: int) -> WorkflowSpec:
    """1 pair-write + READERS_PER_WF auditing read-only steps."""
    spec = WorkflowSpec(f"rh-{wf}")
    k1 = f"rh/{wf % PAIR_KEYSPACE}/a"
    k2 = f"rh/{wf % PAIR_KEYSPACE}/b"
    payload = f"wf-{wf}".encode()

    def writer(ctx):
        # both keys of the pair always carry identical payloads, so any
        # reader observing two different values has a fractured read
        ctx.put(k1, payload)
        ctx.put(k2, payload)
        return wf

    spec.step("w", writer)

    def audit(ctx):
        v1 = ctx.get(k1)
        v2 = ctx.get(k2)
        return 1 if (v1 is not None and v2 is not None and v1 != v2) else 0

    spec.fan_out("r", audit, READERS_PER_WF, deps=("w",),
                 reads=lambda i: (k1, k2), read_only=True)
    spec.validate()
    return spec


def _run_lane(workflows: int, ts: float, seed: int, lane_on: bool) -> Dict:
    store = engine("dynamodb", ts, seed=seed)
    platform = LambdaPlatform(FaasConfig(time_scale=ts, warm_latency_ms=0.0,
                                         seed=seed))
    cluster = make_cluster(store, nodes=3, time_scale=ts)
    cfg = PoolConfig(scope=TxnScope.STEP, max_attempts=10,
                     batch_max_steps=16, max_inflight_steps=256,
                     read_only_lane=lane_on)
    t0 = time.perf_counter()
    with WorkflowPool(platform, cluster=cluster, config=cfg) as pool:
        tickets = [pool.submit(build_read_heavy_spec(i))
                   for i in range(workflows)]
        results = [t.result(timeout=600) for t in tickets]
    wall = time.perf_counter() - t0
    steps = sum(r.steps_run for r in results)
    anomalies = sum(
        v for r in results for n, v in r.results.items() if n.startswith("r[")
    )
    out = {
        "read_only_lane": lane_on,
        "workflows": workflows,
        "read_step_fraction": round(READERS_PER_WF / (READERS_PER_WF + 1), 2),
        "wall_s": round(wall, 3),
        "steps_run": steps,
        "steps_per_s": round(steps / wall, 1),
        "read_atomic_anomalies": anomalies,
    }
    platform.shutdown()
    cluster.stop()
    return out


def _run_snapshot_arm(ts: float, seed: int, keys: int,
                      max_staleness_s: float = 30.0) -> Dict:
    """Write-once keys through one node, bounded-staleness snapshot reads
    from the others: every served snapshot must carry the (only) committed
    payload; stalled gossip may only yield SnapshotUnavailable."""
    cluster = make_cluster(engine("dynamodb", ts, seed=seed), nodes=3,
                           time_scale=ts)
    writer, *readers = cluster.nodes
    tids = {}
    for i in range(keys):
        tx = writer.start_transaction()
        writer.put(tx, f"snap/{i}", f"v{i}".encode())
        tids[i] = writer.commit_transaction(tx)
    # wait (bounded) for the gossiped watermark to cover the last commit
    deadline = time.monotonic() + 10.0
    last_ts = tids[keys - 1].timestamp
    while time.monotonic() < deadline and any(
        r.read_watermark_ns() < last_ts for r in readers
    ):
        time.sleep(0.02)
    served = unavailable = wrong = 0
    for i in range(keys):
        for reader in readers:
            try:
                snap = reader.snapshot_read(f"snap/{i}", max_staleness_s)
            except SnapshotUnavailable:
                unavailable += 1
                continue
            served += 1
            if snap.value != f"v{i}".encode() or snap.tid != tids[i]:
                wrong += 1
    cluster.stop()
    return {
        "keys": keys,
        "reads": keys * len(readers),
        "served": served,
        "unavailable": unavailable,
        "wrong_values": wrong,
    }


def run_read_heavy(quick: bool = True) -> Dict:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    workflows = 60 if smoke else (120 if quick else 320)
    ts = LANE_TIME_SCALE
    # tracing on for the whole arm: REPRO_TRACE_FILE adds the file sink
    # (the CI obs-check hook replays it through the offline checker); the
    # ring buffer alone feeds the inline check below either way
    prev_tracer = obs_trace.get_tracer()
    tracer = obs_trace.enable(
        path=os.environ.get(obs_trace.TRACE_FILE_ENV), capacity=500_000
    )
    # best-of-2 per arm: wall time on a shared container swings with
    # scheduler noise; the max steps/sec is the run least perturbed by it
    # (applied symmetrically, so the ratio is not biased).  The audit and
    # checker counters aggregate over every run — anomaly gates see all.
    def best_of(lane_on: bool) -> Dict:
        runs = [_run_lane(workflows, ts, seed=workflows + i, lane_on=lane_on)
                for i in range(2)]
        best = max(runs, key=lambda r: r["steps_per_s"])
        best["read_atomic_anomalies"] = sum(
            r["read_atomic_anomalies"] for r in runs
        )
        return best

    try:
        lane_off = best_of(False)
        lane_on = best_of(True)
        snapshot = _run_snapshot_arm(ts, seed=workflows,
                                     keys=8 if smoke else 32)
    finally:
        obs_trace.set_tracer(prev_tracer)
        tracer.close()
    checked = check_events(tracer.events())
    return {
        "lane_off": lane_off,
        "lane_on": lane_on,
        "speedup_steps_per_s": round(
            lane_on["steps_per_s"] / max(lane_off["steps_per_s"], 1e-9), 2
        ),
        "read_atomic_anomalies": (
            lane_on["read_atomic_anomalies"]
            + lane_off["read_atomic_anomalies"]
        ),
        "snapshot": snapshot,
        "trace_events": len(tracer.events()),
        "checker_violations": len(checked.violations),
    }


def run(quick: bool = True) -> Dict:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    clients = 4 if smoke else 10
    per_client = 10 if smoke else (40 if quick else 1000)
    ts = QUICK_TIME_SCALE
    out: Dict[str, Dict] = {}
    grid = (0, 8, 10) if smoke else (0, 2, 4, 6, 8, 10)
    for reads in grid:
        writes = 10 - reads
        row = {}
        for store in ("dynamodb", "redis"):
            cluster = make_cluster(engine(store, ts), time_scale=ts)
            # single function carrying all 10 IOs (isolates the IO path)
            cfg = workload_cfg(functions=1, reads=reads, writes=writes,
                               time_scale=ts, seed=reads)
            res = run_workload("aft", cfg=cfg, clients=clients,
                               txns_per_client=per_client, cluster=cluster)
            row[f"aft_{store}"] = res.summary()
            cluster.stop()
        out[f"reads_{reads*10}pct"] = row
    out["read_heavy_fast_lane"] = run_read_heavy(quick)
    save("fig5_rw_ratio", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
