"""Fig 5: read/write ratio — 10-IO transactions, reads from 0% to 100%,
AFT over DynamoDB and Redis."""

from __future__ import annotations

from typing import Dict

from repro.faas.workload import run_workload

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg


def run(quick: bool = True) -> Dict:
    clients = 10
    per_client = 40 if quick else 1000
    ts = QUICK_TIME_SCALE
    out: Dict[str, Dict] = {}
    for reads in (0, 2, 4, 6, 8, 10):
        writes = 10 - reads
        row = {}
        for store in ("dynamodb", "redis"):
            cluster = make_cluster(engine(store, ts), time_scale=ts)
            # single function carrying all 10 IOs (isolates the IO path)
            cfg = workload_cfg(functions=1, reads=reads, writes=writes,
                               time_scale=ts, seed=reads)
            res = run_workload("aft", cfg=cfg, clients=clients,
                               txns_per_client=per_client, cluster=cluster)
            row[f"aft_{store}"] = res.summary()
            cluster.stop()
        out[f"reads_{reads*10}pct"] = row
    save("fig5_rw_ratio", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
