"""Fig 2: IO latency of 1/5/10 writes — DynamoDB direct (sequential vs
batch) vs through AFT (sequential vs batch).  Single client, no FaaS layer."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from .common import QUICK_TIME_SCALE, engine, make_cluster, save


def _percentiles(xs: List[float]) -> Dict[str, float]:
    a = np.asarray(xs)
    return {"median_ms": round(float(np.percentile(a, 50)), 3),
            "p99_ms": round(float(np.percentile(a, 99)), 3)}


def run(quick: bool = True) -> Dict:
    n_reqs = 200 if quick else 1000
    ts = QUICK_TIME_SCALE
    payload = b"x" * 4096
    out: Dict[str, Dict] = {}
    for n_writes in (1, 5, 10):
        row: Dict[str, Dict] = {}
        # --- direct to DynamoDB, sequential
        eng = engine("dynamodb", ts)
        lat = []
        for i in range(n_reqs):
            t0 = time.perf_counter()
            for w in range(n_writes):
                eng.put(f"k{i}-{w}", payload)
            lat.append((time.perf_counter() - t0) * 1e3 / ts)
        row["dynamo_sequential"] = _percentiles(lat)
        # --- direct, batch
        eng = engine("dynamodb", ts)
        lat = []
        for i in range(n_reqs):
            t0 = time.perf_counter()
            eng.put_batch({f"k{i}-{w}": payload for w in range(n_writes)})
            lat.append((time.perf_counter() - t0) * 1e3 / ts)
        row["dynamo_batch"] = _percentiles(lat)
        # --- through AFT: sequential puts, commit batches via write buffer
        for mode in ("aft_sequential", "aft_batch"):
            cluster = make_cluster(engine("dynamodb", ts), time_scale=ts)
            node = cluster.live_nodes()[0]
            lat = []
            for i in range(n_reqs):
                t0 = time.perf_counter()
                txid = node.start_transaction()
                # sequential: n separate client→AFT puts (client RTT each);
                # batch: one request carrying all writes.  The per-put
                # client→AFT hop is ~0.5ms (same-AZ RPC).
                for w in range(n_writes):
                    if mode == "aft_sequential":
                        time.sleep(0.0005 * ts * 1e3 / 1e3)
                    node.put(txid, f"k{i}-{w}", payload)
                node.commit_transaction(txid)
                lat.append((time.perf_counter() - t0) * 1e3 / ts)
            row[mode] = _percentiles(lat)
            cluster.stop()
        out[f"writes_{n_writes}"] = row
    save("fig2_io_latency", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
