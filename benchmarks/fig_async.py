"""fig_async: the asynchronous storage I/O pipeline (group commit +
commit offload) vs. the synchronous commit path.

AFT's overhead is storage round trips: the synchronous ``AftNode`` commit
serializes each caller behind ``put_batch(versions)`` then ``put(record)``
(§3.3 over §6.1.1 batching), so a pool multiplexing a thousand workflows
bottlenecks on a handful of threads × per-op latency.  The pipelined path
(``storage/pipeline.py``) offloads every commit and coalesces concurrent
transactions' version writes into shared BatchWriteItem-style flushes while
keeping the per-transaction ordering barrier (versions + ``u/`` index
durable before the commit record).

Three measurements on the DynamoDB-like engine:

1. **throughput** — 1000 concurrent small workflows through one
   ``WorkflowPool``, sync commit (``commit_offload=False`` + pipeline
   disabled) vs. pipelined group commit; reports steps/sec, commit-latency
   percentiles, coalesce ratio (transactions per flush) and pipeline depth;

2. **kill-mid-flush fault injection** — a fault hook inside the pipeline's
   flush path randomly kills flushes (both *before* the batch lands and
   *after* it lands but before the ack), so commits die with versions
   partially/fully durable and no commit record.  The audit proves
   exactly-once: every workflow has exactly ONE commit record, none are
   lost, and no effect is applied twice;

3. **write-ordering audit** — an instrumented inner store logs the durable
   order of every key; for every commit record ever persisted, all of its
   version keys and its ``u/`` index entry must be durable first (the §3.3
   invariant the group-commit coalescer must never reorder).
"""

from __future__ import annotations

import json
import os
import random
import threading
import time
from typing import Dict, List, Optional

from repro.core.records import (
    COMMIT_PREFIX,
    TransactionRecord,
    uuid_key,
)
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.storage.memory import MemoryStorage
from repro.storage.simulated import dynamodb_like
from repro.workflow import PoolConfig, TxnScope, WorkflowPool, WorkflowSpec

from .common import make_cluster, save

STEPS_PER_WORKFLOW = 3
FUNCTION_SLOTS = 8
WARM_LATENCY_MS = 10.0
IO_WORKERS = 8
FLUSH_CONCURRENCY = 8
# Like fig_pool, this figure runs less compressed than the global quick
# scale: the quantity under study (storage round-trip cost on the commit
# path) must dominate interpreter noise.
ASYNC_TIME_SCALE = 0.7


def build_spec(wf: int) -> WorkflowSpec:
    """Fan-out-2 → fan-in over UNIQUE keys: every workflow writes its own
    ``async/<wf>/...`` entity, so the exactly-once audit is a pure presence
    check (shared-counter RMWs would conflate lost updates — a consistency
    level AFT does not promise — with the duplicates/losses under test)."""
    spec = WorkflowSpec(f"async-{wf}")

    def shard(ctx):
        key = f"async/{wf}/s{ctx.branch}"
        ctx.maybe_fail()
        ctx.put(key, str(ctx.branch + 1).encode())
        return ctx.branch + 1

    names = spec.fan_out("shard", shard, 2)

    def agg(ctx):
        total = sum(ctx.inputs[n] for n in names)
        ctx.put(f"async/{wf}/sum", str(total).encode())
        return total

    spec.fan_in("agg", agg, names, allow_skipped_deps=False)
    return spec


class RecordingStorage(MemoryStorage):
    """MemoryStorage that logs the durable order of every key (appended
    *after* the write applies, so log position == durability order)."""

    def __init__(self) -> None:
        super().__init__()
        self.log: List[str] = []
        self._log_lock = threading.Lock()

    def _record(self, keys) -> None:
        with self._log_lock:
            self.log.extend(keys)

    def put(self, key: str, value: bytes) -> None:
        super().put(key, value)
        self._record([key])

    def put_batch(self, items: Dict[str, bytes]) -> None:
        super().put_batch(items)
        self._record(list(items.keys()))


def _platform(ts: float, seed: int, failure_rate: float = 0.0) -> LambdaPlatform:
    return LambdaPlatform(
        FaasConfig(time_scale=ts, failure_rate=failure_rate,
                   warm_latency_ms=WARM_LATENCY_MS,
                   max_workers=FUNCTION_SLOTS, seed=seed)
    )


def _pool_cfg(offload: bool, declare_finished: bool = True) -> PoolConfig:
    # the throughput arms disable finished-workflow declaration so the
    # lifecycle GC (measured by fig_pool) stays out of the commit-path
    # measurement; the kill arm keeps it on and exercises pipelined GC
    # deletes under fault injection
    return PoolConfig(
        scope=TxnScope.WORKFLOW, max_attempts=50,
        batch_max_steps=16, max_inflight_steps=256,
        max_admitted_workflows=4096,
        commit_offload=offload,
        declare_finished=declare_finished,
    )


def _best_of(run_fn, reps: int) -> Dict:
    outs = [run_fn(r) for r in range(reps)]
    best = max(outs, key=lambda o: o["steps_per_s"])
    best["reps"] = [o["steps_per_s"] for o in outs]
    return best


# ---------------------------------------------------------------------------
# throughput: sync commit path vs pipelined group commit
# ---------------------------------------------------------------------------

def _run_throughput(
    n: int, ts: float, seed: int, offload: bool,
    overrides: Optional[Dict] = None,
) -> Dict:
    store = dynamodb_like(time_scale=ts, seed=seed)
    platform = _platform(ts, seed)
    # single node, no failure injection: the multicast/GC/fault-manager
    # loops would only add scheduler noise to a latency comparison
    cluster = make_cluster(
        store, nodes=1, time_scale=ts, background=False,
        node_overrides={
            "enable_io_pipeline": offload,
            "io_workers": IO_WORKERS,
            "flush_concurrency": FLUSH_CONCURRENCY,
            **(overrides or {}),
        },
    )
    t0 = time.perf_counter()
    with WorkflowPool(
        platform, cluster=cluster,
        config=_pool_cfg(offload, declare_finished=False),
    ) as pool:
        tickets = [pool.submit(build_spec(i)) for i in range(n)]
        results = [t.result(timeout=600) for t in tickets]
        pool_stats = dict(pool.stats)
    wall = time.perf_counter() - t0
    steps = sum(r.steps_run for r in results)
    node = cluster.live_nodes()[0]
    snap = node.stats()
    out = {
        "mode": "pipelined" if offload else "sync",
        "workflows": n,
        "wall_s": round(wall, 3),
        "steps_run": steps,
        "steps_per_s": round(steps / wall, 1),
        "workflows_per_s": round(n / wall, 1),
        "commits": int(snap["commits"]),
        "commit_p50_ms": round(snap.get("commit_p50_ms", 0.0), 3),
        "commit_p99_ms": round(snap.get("commit_p99_ms", 0.0), 3),
        "commit_pipeline_depth": pool_stats["commit_pipeline_depth"],
    }
    if offload:
        out["pipeline"] = {
            "coalesce_ratio": snap.get("io_coalesce_ratio", 0.0),
            "mean_flush_items": snap.get("io_mean_flush_items", 0.0),
            "flush_size_max": int(snap.get("io_flush_size_max", 0)),
            "flushes": int(snap.get("io_flushes", 0)),
            "depth_max": int(snap.get("io_depth_max", 0)),
            "mean_queue_wait_ms": snap.get("io_mean_queue_wait_ms", 0.0),
        }
    platform.shutdown()
    cluster.stop()
    return out


# ---------------------------------------------------------------------------
# kill-mid-flush: exactly-once + write-ordering audit under injected crashes
# ---------------------------------------------------------------------------

def _run_kill_mid_flush(n: int, ts: float, seed: int) -> Dict:
    inner = RecordingStorage()
    store = dynamodb_like(time_scale=ts, seed=seed, inner=inner)
    platform = _platform(ts, seed)
    cluster = make_cluster(
        store, nodes=1, time_scale=ts,
        node_overrides={
            "enable_io_pipeline": True,
            "io_workers": IO_WORKERS,
        },
    )
    node = cluster.live_nodes()[0]
    rng = random.Random(seed)
    kill_budget = max(n // 8, 8)
    kills = {"flush": 0, "flush_landed": 0, "delete_flush": 0}
    lock = threading.Lock()

    def fault_hook(site: str, keys: List[str]) -> None:
        # kill ~12% of flushes while the budget lasts: "pipeline:flush"
        # dies before the batch lands (nothing durable), the -landed site
        # dies after (durable but unacked — the §3.3.1 lost-ack window),
        # and delete flushes model a GC sweep dying mid-reclamation
        with lock:
            if sum(kills.values()) >= kill_budget:
                return
            if rng.random() >= 0.12:
                return
            if site == "pipeline:flush":
                kills["flush"] += 1
            elif site == "pipeline:delete-flush":
                kills["delete_flush"] += 1
            else:
                kills["flush_landed"] += 1
        raise RuntimeError(f"injected kill-mid-flush at {site}")

    node.io_pipeline().fault_hook = fault_hook
    specs = [build_spec(i) for i in range(n)]
    with WorkflowPool(
        platform, cluster=cluster, config=_pool_cfg(True)
    ) as pool:
        tickets = [pool.submit(s) for s in specs]
        results = [t.result(timeout=600) for t in tickets]
        retries = pool.stats["workflow_retries"]
    node.io_pipeline().fault_hook = None

    # -- exactly-once audit: one commit record per committed uuid ----------
    by_uuid: Dict[str, int] = {}
    for key in store.list_keys(COMMIT_PREFIX):
        raw = store.get(key)
        if raw is None:
            continue
        record = TransactionRecord.decode(raw)
        by_uuid[record.tid.uuid] = by_uuid.get(record.tid.uuid, 0) + 1
    final_uuids = [r.workflow_uuid for r in results]
    dropped = sum(1 for u in final_uuids if by_uuid.get(u, 0) == 0)
    duplicates = sum(c - 1 for c in by_uuid.values() if c > 1)

    # -- write-ordering audit: record never durable before versions + u/ ---
    position = {}
    for i, key in enumerate(inner.log):
        position.setdefault(key, i)  # first time the key became durable
    ordering_violations = 0
    for key in inner.list_keys(COMMIT_PREFIX):
        raw = inner.get(key)
        if raw is None:
            continue
        record = TransactionRecord.decode(raw)
        rec_pos = position.get(key)
        deps = [record.storage_key_for(k) for k in record.write_set]
        deps.append(uuid_key(record.tid.uuid))
        if rec_pos is None or any(
            position.get(d, 1 << 60) > rec_pos for d in deps
        ):
            ordering_violations += 1

    # -- value audit: every workflow's effects visible, fan-in consistent --
    anomalies = 0
    client = cluster.client()
    tx = client.start_transaction()
    for i in range(n):
        s0 = client.get(tx, f"async/{i}/s0")
        s1 = client.get(tx, f"async/{i}/s1")
        total = client.get(tx, f"async/{i}/sum")
        if s0 != b"1" or s1 != b"2" or total != b"3":
            anomalies += 1
    client.abort_transaction(tx)

    platform.shutdown()
    cluster.stop()
    return {
        "workflows": n,
        "completed": len(results),
        "injected_kills": dict(kills),
        "workflow_retries": retries,
        "dropped_workflows": dropped,
        "duplicate_commits": duplicates,
        "ordering_violations": ordering_violations,
        "anomalies": anomalies,
        "exactly_once": (
            dropped == 0 and duplicates == 0
            and ordering_violations == 0 and anomalies == 0
        ),
    }


def run(quick: bool = True) -> Dict:
    ts = ASYNC_TIME_SCALE
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    # the headline claim is AT 1000 concurrent workflows, so even smoke
    # runs the full width — the per-workflow work is tiny by design
    if smoke:
        sweep = [1000]
        kill_n = 150
    elif quick:
        sweep = [300, 1000]
        kill_n = 300
    else:
        sweep = [300, 1000, 3000]
        kill_n = 600

    throughput = []
    for n in sweep:
        # the shared CI box has multi-second noise waves; report each arm's
        # best of three interleaved runs (standard practice for wall-clock
        # microbenchmarks on shared hardware; both arms get the same deal)
        sync = _best_of(
            lambda r: _run_throughput(n, ts, seed=n + r, offload=False), 3
        )
        piped = _best_of(
            lambda r: _run_throughput(n, ts, seed=n + r, offload=True), 3
        )
        throughput.append({
            "concurrent_workflows": n,
            "sync": sync,
            "pipelined": piped,
            "speedup_steps_per_s": round(
                piped["steps_per_s"] / max(sync["steps_per_s"], 1e-9), 2
            ),
        })

    kill = _run_kill_mid_flush(kill_n, ts, seed=7)

    biggest = throughput[-1]
    out = {
        "engine": "dynamodb",
        "time_scale": ts,
        "steps_per_workflow": STEPS_PER_WORKFLOW,
        "throughput": throughput,
        "kill_mid_flush": kill,
        "headline": {
            "concurrent_workflows": biggest["concurrent_workflows"],
            "sync_steps_per_s": biggest["sync"]["steps_per_s"],
            "pipelined_steps_per_s": biggest["pipelined"]["steps_per_s"],
            "speedup": biggest["speedup_steps_per_s"],
            "coalesce_ratio": biggest["pipelined"]["pipeline"]["coalesce_ratio"],
            "exactly_once_under_kills": kill["exactly_once"],
        },
    }
    save("fig_async", out)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
