"""Fig 3 + Table 2: end-to-end latency and anomaly counts for 2-function
6-IO transactions over S3 / DynamoDB / Redis, plain vs AFT (and DynamoDB
transaction mode), 10 parallel clients × N txns, Zipf 1.0."""

from __future__ import annotations

from typing import Dict

from repro.faas.workload import run_workload

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg


def run(quick: bool = True) -> Dict:
    clients = 10
    per_client = 60 if quick else 1000
    ts = QUICK_TIME_SCALE
    out: Dict[str, Dict] = {}

    for name in ("s3", "dynamodb", "redis"):
        cfg = workload_cfg(zipf=1.0, time_scale=ts, seed=hash(name) % 997)
        # plain: direct writes, metadata embedded for anomaly detection
        res = run_workload("plain", cfg=cfg, clients=clients,
                           txns_per_client=per_client,
                           storage=engine(name, ts))
        out[f"{name}_plain"] = res.summary()
        # AFT interposed over the same engine
        cluster = make_cluster(engine(name, ts), time_scale=ts)
        res = run_workload("aft", cfg=cfg, clients=clients,
                           txns_per_client=per_client, cluster=cluster)
        out[f"{name}_aft"] = res.summary()
        cluster.stop()

    # DynamoDB transaction mode (read-only + write-only txns, §6.1.2)
    cfg = workload_cfg(zipf=1.0, time_scale=ts, seed=13)
    res = run_workload("dynamo_txn", cfg=cfg, clients=clients,
                       txns_per_client=per_client,
                       storage=engine("dynamodb", ts))
    out["dynamodb_txn_mode"] = res.summary()

    # Table-2 view
    table2 = {
        "AFT (read atomic)": {
            "ryw": out["dynamodb_aft"]["ryw_anomalies"],
            "fr": out["dynamodb_aft"]["fr_anomalies"]},
        "S3 (none)": {"ryw": out["s3_plain"]["ryw_anomalies"],
                      "fr": out["s3_plain"]["fr_anomalies"]},
        "DynamoDB (none)": {"ryw": out["dynamodb_plain"]["ryw_anomalies"],
                            "fr": out["dynamodb_plain"]["fr_anomalies"]},
        "DynamoDB (txn mode)": {
            "ryw": out["dynamodb_txn_mode"]["ryw_anomalies"],
            "fr": out["dynamodb_txn_mode"]["fr_anomalies"]},
        "Redis (shard-linearizable)": {
            "ryw": out["redis_plain"]["ryw_anomalies"],
            "fr": out["redis_plain"]["fr_anomalies"]},
    }
    payload = {"fig3": out, "table2": table2,
               "txns_per_config": clients * per_client}
    save("fig3_table2_e2e", payload)
    return payload


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
