"""Fig 7: single-node scalability — throughput vs parallel clients (1..50),
AFT over DynamoDB and Redis, Zipf 1.5."""

from __future__ import annotations

from typing import Dict

from repro.faas.workload import run_workload

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg


def run(quick: bool = True) -> Dict:
    per_client = 20 if quick else 1000
    # scalability figures use milder time compression: at 0.03 the simulated
    # IO shrinks below python-thread overheads and the curve measures the
    # GIL, not the shim.  0.2 keeps sim latency ≫ scheduler noise.
    ts = 0.2
    client_counts = (1, 5, 10, 20, 30, 40, 50)
    out: Dict[str, Dict] = {}
    for store in ("dynamodb", "redis"):
        row = {}
        for clients in client_counts:
            cluster = make_cluster(engine(store, ts), time_scale=ts)
            cfg = workload_cfg(zipf=1.5, time_scale=ts, seed=clients)
            res = run_workload("aft", cfg=cfg, clients=clients,
                               txns_per_client=per_client, cluster=cluster)
            row[f"clients_{clients}"] = res.summary()
            cluster.stop()
        out[store] = row
    save("fig7_single_node", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
