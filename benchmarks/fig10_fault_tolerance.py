"""Fig 10: fault tolerance — 4 nodes + standby, kill one mid-run, track
throughput over time through detection, replacement, cache warm-up and
recovery."""

from __future__ import annotations

import threading
import time
from typing import Dict, List

from repro.faas.workload import build_txn_spec, run_aft_transaction, ZipfSampler
from repro.faas.platform import FaasConfig, LambdaPlatform
from repro.core.anomaly import AnomalyAggregator

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg


def run(quick: bool = True) -> Dict:
    ts = QUICK_TIME_SCALE
    clients = 24
    duration_s = 12.0 if quick else 30.0
    kill_at_s = duration_s * 0.25
    cluster = make_cluster(engine("dynamodb", ts), nodes=4, standby=1,
                           time_scale=ts, fast_failover=True)
    cfg = workload_cfg(zipf=1.5, time_scale=ts, seed=3)
    platform = LambdaPlatform(FaasConfig(time_scale=ts, max_workers=64))
    agg = AnomalyAggregator("aft")
    completions: List[float] = []
    lock = threading.Lock()
    stop = threading.Event()
    t0 = time.perf_counter()

    def client_loop(ci: int) -> None:
        sampler = ZipfSampler(cfg.num_keys, cfg.zipf, seed=97 * ci)
        while not stop.is_set():
            spec = build_txn_spec(cfg, sampler)
            try:
                run_aft_transaction(cluster, platform, spec, cfg, agg)
            except Exception:
                continue
            with lock:
                completions.append(time.perf_counter() - t0)

    threads = [threading.Thread(target=client_loop, args=(i,), daemon=True)
               for i in range(clients)]
    for t in threads:
        t.start()
    time.sleep(kill_at_s)
    dead = cluster.kill_node(0)
    kill_time = time.perf_counter() - t0
    time.sleep(duration_s - kill_at_s)
    stop.set()
    for t in threads:
        t.join(timeout=5)
    platform.shutdown()

    # throughput time series in 0.5 s buckets
    bucket = 0.5
    nb = int(duration_s / bucket) + 1
    series = [0] * nb
    for c in completions:
        bi = min(int(c / bucket), nb - 1)
        series[bi] += 1
    tps = [round(n / bucket, 1) for n in series]
    pre = [v for i, v in enumerate(tps) if (i + 1) * bucket <= kill_time]
    post_window = tps[-3:]
    out = {
        "kill_time_s": round(kill_time, 2),
        "bucket_s": bucket,
        "tps_series": tps,
        "pre_kill_tps": round(sum(pre) / max(len(pre), 1), 1),
        "recovered_tps": round(sum(post_window) / len(post_window), 1),
        "nodes_replaced": cluster.fault_manager.stats.get("nodes_replaced", 0),
        "recovered_commits": cluster.fault_manager.stats.get(
            "recovered_commits", 0),
        "anomalies": agg.summary(),
        "total_txns": len(completions),
    }
    cluster.stop()
    save("fig10_fault_tolerance", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
