"""Fig 4: read caching × access skew — AFT over DynamoDB / Redis with and
without the data cache, plus DynamoDB transaction mode, Zipf ∈ {1.0, 1.5,
2.0} over a 100k key space."""

from __future__ import annotations

from typing import Dict

from repro.faas.workload import run_workload

from .common import QUICK_TIME_SCALE, engine, make_cluster, save, workload_cfg


def run(quick: bool = True) -> Dict:
    clients = 10
    per_client = 40 if quick else 1000
    num_keys = 10_000 if quick else 100_000
    ts = QUICK_TIME_SCALE
    out: Dict[str, Dict] = {}
    for zipf in (1.0, 1.5, 2.0):
        row: Dict[str, Dict] = {}
        for store in ("dynamodb", "redis"):
            for cache in (True, False):
                cluster = make_cluster(engine(store, ts), data_cache=cache,
                                       time_scale=ts)
                cfg = workload_cfg(zipf=zipf, num_keys=num_keys,
                                   time_scale=ts, seed=int(zipf * 10))
                res = run_workload("aft", cfg=cfg, clients=clients,
                                   txns_per_client=per_client,
                                   cluster=cluster)
                row[f"aft_{store}_{'cache' if cache else 'nocache'}"] = \
                    res.summary()
                cluster.stop()
        cfg = workload_cfg(zipf=zipf, num_keys=num_keys, time_scale=ts,
                           seed=int(zipf * 10))
        res = run_workload("dynamo_txn", cfg=cfg, clients=clients,
                           txns_per_client=per_client,
                           storage=engine("dynamodb", ts))
        row["dynamodb_txn_mode"] = res.summary()
        out[f"zipf_{zipf}"] = row
    save("fig4_caching_skew", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
